//! GNMTv2-style attentional seq2seq (Wu et al. 2016, as benchmarked in the
//! paper): LSTM encoder, LSTM decoder with dot-product attention over
//! encoder states, shared output projection. Scaled: d=128, 2+2 layers,
//! vocab 4k, len 16. Throughput unit: target tokens/s (Table 1).

use super::{Batch, BenchModel};
use crate::nn::{Embedding, Linear, Module, LSTM};
use crate::ops;
use crate::tensor::Tensor;

/// Scaled GNMTv2.
pub struct Gnmt {
    pub embed: Embedding,
    pub encoder: LSTM,
    pub decoder: LSTM,
    pub attn_out: Linear,
    pub proj: Linear,
    pub vocab: usize,
    pub dim: usize,
    pub batch: usize,
    pub src_len: usize,
    pub tgt_len: usize,
}

impl Gnmt {
    pub fn table1() -> Gnmt {
        Gnmt::new(4096, 128, 2, 32, 16, 16)
    }

    pub fn new(vocab: usize, dim: usize, layers: usize, batch: usize, src_len: usize, tgt_len: usize) -> Gnmt {
        Gnmt {
            embed: Embedding::new(vocab, dim),
            encoder: LSTM::new(dim, dim, layers),
            decoder: LSTM::new(dim, dim, layers),
            attn_out: Linear::new(2 * dim, dim),
            proj: Linear::new(dim, vocab),
            vocab,
            dim,
            batch,
            src_len,
            tgt_len,
        }
    }

    /// Embed a [N, T] i64 token tensor into [T, N, D] (time-major).
    fn embed_seq(&self, tokens: &Tensor) -> Tensor {
        let emb = self.embed.forward(tokens); // [N, T, D]
        emb.permute(&[1, 0, 2]).contiguous() // [T, N, D]
    }

    /// Forward + mean cross-entropy over target positions (teacher forcing:
    /// input is tgt shifted right via zero BOS; label is tgt itself).
    pub fn seq_loss(&self, src: &Tensor, tgt: &Tensor) -> Tensor {
        let n = src.size(0);
        let t_len = tgt.size(1);

        // Encode.
        let src_emb = self.embed_seq(src);
        let (enc_states, final_state) = self.encoder.run(&src_emb, None); // [S, N, D]
        // Attention memory: [N, S, D].
        let memory = enc_states.permute(&[1, 0, 2]).contiguous();
        let memory_t = memory.transpose(1, 2).contiguous(); // [N, D, S]

        // Decoder input: BOS (zeros) + tgt[:-1].
        let tgt_in = {
            let bos = Tensor::zeros_on(&[n, 1], crate::tensor::DType::I64, tgt.device());
            let shifted = tgt.narrow(1, 0, t_len - 1);
            ops::cat(&[&bos, &shifted], 1)
        };
        let tgt_emb = self.embed_seq(&tgt_in); // [T, N, D]
        let (dec_states, _) = self.decoder.run(&tgt_emb, Some(final_state)); // [T, N, D]

        // Dot attention for all steps at once: scores [N, T, S].
        let dec_btd = dec_states.permute(&[1, 0, 2]).contiguous(); // [N, T, D]
        let scores = ops::bmm(&dec_btd, &memory_t); // [N, T, S]
        let weights = ops::softmax_last(&ops::mul_scalar(&scores, 1.0 / (self.dim as f32).sqrt()));
        let context = ops::bmm(&weights, &memory); // [N, T, D]
        let combined = ops::cat(&[&context, &dec_btd], 2); // [N, T, 2D]
        let attn = ops::tanh(&self.attn_out.forward(&combined)); // [N, T, D]

        // Project to vocab and compute token-level cross entropy.
        let logits = self.proj.forward(&attn); // [N, T, V]
        let flat_logits = logits.reshape(&[n * t_len, self.vocab]);
        let flat_tgt = tgt.reshape(&[n * t_len]);
        ops::cross_entropy(&flat_logits, &flat_tgt)
    }
}

impl BenchModel for Gnmt {
    fn name(&self) -> &'static str {
        "gnmt"
    }

    fn parameters(&self) -> Vec<Tensor> {
        let mut p = self.embed.parameters();
        p.extend(Module::parameters(&self.encoder));
        p.extend(Module::parameters(&self.decoder));
        p.extend(self.attn_out.parameters());
        p.extend(self.proj.parameters());
        p
    }

    fn loss(&self, batch: &Batch) -> Tensor {
        match batch {
            Batch::Seq2Seq(src, tgt) => self.seq_loss(src, tgt),
            _ => crate::torsk_bail!("gnmt expects a seq2seq batch"),
        }
    }

    fn make_batch(&self, seed: u64) -> Batch {
        let mut r = crate::rng::Rng::new(seed);
        let src: Vec<i64> =
            (0..self.batch * self.src_len).map(|_| r.below(self.vocab as u64) as i64).collect();
        let tgt: Vec<i64> =
            (0..self.batch * self.tgt_len).map(|_| r.below(self.vocab as u64) as i64).collect();
        Batch::Seq2Seq(
            Tensor::from_vec(src, &[self.batch, self.src_len]),
            Tensor::from_vec(tgt, &[self.batch, self.tgt_len]),
        )
    }

    fn set_training(&mut self, _training: bool) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Gnmt {
        crate::rng::manual_seed(0);
        Gnmt::new(50, 16, 1, 2, 5, 4)
    }

    #[test]
    fn loss_is_near_log_vocab_at_init() {
        let m = tiny();
        let b = m.make_batch(1);
        let loss = m.loss(&b).item();
        let expect = (50f32).ln();
        assert!((loss - expect).abs() < 1.0, "loss {loss} vs ln(V) {expect}");
    }

    #[test]
    fn backward_reaches_all_components() {
        let m = tiny();
        let b = m.make_batch(1);
        m.loss(&b).backward();
        assert!(m.embed.weight.grad().is_some(), "embedding grad");
        assert!(m.proj.weight.grad().is_some(), "projection grad");
        assert!(m.attn_out.weight.grad().is_some(), "attention grad");
        for p in Module::parameters(&m.encoder) {
            assert!(p.grad().is_some(), "encoder grad");
        }
        for p in Module::parameters(&m.decoder) {
            assert!(p.grad().is_some(), "decoder grad");
        }
    }

    #[test]
    fn one_sgd_step_reduces_loss() {
        use crate::optim::{Optimizer, Sgd};
        let m = tiny();
        let b = m.make_batch(2);
        let mut opt = Sgd::new(m.parameters(), 0.5);
        let l0 = m.loss(&b);
        l0.backward();
        opt.step();
        let l1 = m.loss(&b);
        assert!(l1.item() < l0.item(), "{} -> {}", l0.item(), l1.item());
    }
}
