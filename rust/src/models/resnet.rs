//! ResNet-50 (He et al. 2015): full [3,4,6,3] bottleneck layout at width/4
//! on 32×32 inputs. The residual additions exercise the autograd engine's
//! fan-in accumulation (the diamond pattern).

use super::{image_batch, Batch, BenchModel};
use crate::nn::{BatchNorm2d, Conv2d, GlobalAvgPool, Linear, Module};
use crate::ops;
use crate::tensor::Tensor;

struct Bottleneck {
    conv1: Conv2d,
    bn1: BatchNorm2d,
    conv2: Conv2d,
    bn2: BatchNorm2d,
    conv3: Conv2d,
    bn3: BatchNorm2d,
    downsample: Option<(Conv2d, BatchNorm2d)>,
}

const EXPANSION: usize = 4;

impl Bottleneck {
    fn new(c_in: usize, width: usize, stride: usize) -> Bottleneck {
        let c_out = width * EXPANSION;
        let downsample = if stride != 1 || c_in != c_out {
            Some((
                Conv2d::with_groups(c_in, c_out, 1, stride, 0, 1, false),
                BatchNorm2d::new(c_out),
            ))
        } else {
            None
        };
        Bottleneck {
            conv1: Conv2d::with_groups(c_in, width, 1, 1, 0, 1, false),
            bn1: BatchNorm2d::new(width),
            conv2: Conv2d::with_groups(width, width, 3, stride, 1, 1, false),
            bn2: BatchNorm2d::new(width),
            conv3: Conv2d::with_groups(width, c_out, 1, 1, 0, 1, false),
            bn3: BatchNorm2d::new(c_out),
            downsample,
        }
    }
}

impl Module for Bottleneck {
    fn forward(&self, x: &Tensor) -> Tensor {
        let mut out = ops::relu(&self.bn1.forward(&self.conv1.forward(x)));
        out = ops::relu(&self.bn2.forward(&self.conv2.forward(&out)));
        out = self.bn3.forward(&self.conv3.forward(&out));
        let identity = match &self.downsample {
            Some((conv, bn)) => bn.forward(&conv.forward(x)),
            None => x.clone(),
        };
        ops::relu(&ops::add(&out, &identity))
    }

    fn parameters(&self) -> Vec<Tensor> {
        let mut p = vec![];
        p.extend(self.conv1.parameters());
        p.extend(self.bn1.parameters());
        p.extend(self.conv2.parameters());
        p.extend(self.bn2.parameters());
        p.extend(self.conv3.parameters());
        p.extend(self.bn3.parameters());
        if let Some((c, b)) = &self.downsample {
            p.extend(c.parameters());
            p.extend(b.parameters());
        }
        p
    }

    fn buffers(&self) -> Vec<Tensor> {
        let mut b = vec![];
        b.extend(self.bn1.buffers());
        b.extend(self.bn2.buffers());
        b.extend(self.bn3.buffers());
        if let Some((_, bn)) = &self.downsample {
            b.extend(bn.buffers());
        }
        b
    }

    fn set_training(&mut self, training: bool) {
        self.bn1.set_training(training);
        self.bn2.set_training(training);
        self.bn3.set_training(training);
        if let Some((_, bn)) = &mut self.downsample {
            bn.set_training(training);
        }
    }

    fn name(&self) -> &'static str {
        "Bottleneck"
    }
}

/// ResNet-50: stem + [3,4,6,3] bottleneck stages + fc.
pub struct ResNet50 {
    stem_conv: Conv2d,
    stem_bn: BatchNorm2d,
    stages: Vec<Bottleneck>,
    pool: GlobalAvgPool,
    fc: Linear,
    pub classes: usize,
    pub batch: usize,
    pub input: (usize, usize, usize),
}

impl ResNet50 {
    pub fn table1() -> ResNet50 {
        ResNet50::new(3, 32, 10, 16)
    }

    pub fn new(c_in: usize, hw: usize, classes: usize, batch: usize) -> ResNet50 {
        // Original stage widths /4: 64,128,256,512 -> 16,32,64,128.
        let widths = [16usize, 32, 64, 128];
        let blocks = [3usize, 4, 6, 3];
        let mut stages = Vec::new();
        let mut c = 16;
        for (s, (&w, &n)) in widths.iter().zip(blocks.iter()).enumerate() {
            for b in 0..n {
                // CIFAR-style: stage 0 keeps resolution, later stages stride 2
                // on their first block.
                let stride = if b == 0 && s > 0 { 2 } else { 1 };
                stages.push(Bottleneck::new(c, w, stride));
                c = w * EXPANSION;
            }
        }
        ResNet50 {
            stem_conv: Conv2d::with_groups(c_in, 16, 3, 1, 1, 1, false),
            stem_bn: BatchNorm2d::new(16),
            stages,
            pool: GlobalAvgPool,
            fc: Linear::new(128 * EXPANSION, classes),
            classes,
            batch,
            input: (c_in, hw, hw),
        }
    }

    /// Number of bottleneck blocks (should be 16 for ResNet-50).
    pub fn num_blocks(&self) -> usize {
        self.stages.len()
    }
}

impl Module for ResNet50 {
    fn forward(&self, x: &Tensor) -> Tensor {
        let mut out = ops::relu(&self.stem_bn.forward(&self.stem_conv.forward(x)));
        for block in &self.stages {
            out = block.forward(&out);
        }
        self.fc.forward(&self.pool.forward(&out))
    }

    fn parameters(&self) -> Vec<Tensor> {
        let mut p = self.stem_conv.parameters();
        p.extend(self.stem_bn.parameters());
        for b in &self.stages {
            p.extend(b.parameters());
        }
        p.extend(self.fc.parameters());
        p
    }

    fn buffers(&self) -> Vec<Tensor> {
        let mut b = self.stem_bn.buffers();
        for s in &self.stages {
            b.extend(s.buffers());
        }
        b
    }

    fn set_training(&mut self, training: bool) {
        self.stem_bn.set_training(training);
        for b in &mut self.stages {
            b.set_training(training);
        }
    }

    fn name(&self) -> &'static str {
        "ResNet50"
    }
}

impl BenchModel for ResNet50 {
    fn name(&self) -> &'static str {
        "resnet50"
    }
    fn parameters(&self) -> Vec<Tensor> {
        Module::parameters(self)
    }
    fn loss(&self, batch: &Batch) -> Tensor {
        match batch {
            Batch::Images(x, y) => {
                let logits = self.forward(x);
                ops::cross_entropy(&logits, y)
            }
            _ => crate::torsk_bail!("resnet expects image batch"),
        }
    }
    fn make_batch(&self, seed: u64) -> Batch {
        let (c, h, w) = self.input;
        image_batch(seed, self.batch, c, h, w, self.classes)
    }
    fn set_training(&mut self, training: bool) {
        Module::set_training(self, training);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_16_bottlenecks_and_53_convs() {
        crate::rng::manual_seed(0);
        let m = ResNet50::new(3, 32, 10, 1);
        assert_eq!(m.num_blocks(), 16); // 3+4+6+3
        // conv weights: stem 1 + 16*3 + 4 downsamples = 53; plus fc weight.
        let conv_weights = Module::parameters(&m)
            .iter()
            .filter(|p| p.ndim() == 4)
            .count();
        assert_eq!(conv_weights, 53);
    }

    #[test]
    fn forward_shape() {
        crate::rng::manual_seed(0);
        let m = ResNet50::new(3, 32, 10, 1);
        let x = Tensor::randn(&[1, 3, 32, 32]);
        let y = m.forward(&x);
        assert_eq!(y.shape(), &[1, 10]);
    }

    #[test]
    fn residual_gradient_flows_to_stem() {
        crate::rng::manual_seed(0);
        let m = ResNet50::new(3, 32, 10, 1);
        let batch = m.make_batch(0);
        BenchModel::loss(&m, &batch).backward();
        let g = m.stem_conv.weight.grad().expect("stem grad");
        assert!(g.to_vec::<f32>().iter().any(|&v| v != 0.0));
    }
}
