//! AlexNet (Krizhevsky et al. 2012), scaled to 32×32 inputs at width/4.

use super::{image_batch, image_loss, Batch, BenchModel};
use crate::nn::{Conv2d, Dropout, Flatten, Linear, MaxPool2d, Module, ReLU, Sequential};
use crate::tensor::Tensor;

/// AlexNet-style CNN: 5 conv + 3 fc.
pub struct AlexNet {
    net: Sequential,
    pub classes: usize,
    pub batch: usize,
    pub input: (usize, usize, usize),
}

impl AlexNet {
    /// width/4, 32×32 configuration used for Table 1.
    pub fn table1() -> AlexNet {
        AlexNet::new(3, 32, 10, 32)
    }

    pub fn new(c_in: usize, hw: usize, classes: usize, batch: usize) -> AlexNet {
        // Original widths /4: 64,192,384,256,256 -> 16,48,96,64,64.
        let net = Sequential::new()
            .add(Conv2d::new(c_in, 16, 3, 1, 1))
            .add(ReLU)
            .add(MaxPool2d::new(2, 2)) // 16x16
            .add(Conv2d::new(16, 48, 3, 1, 1))
            .add(ReLU)
            .add(MaxPool2d::new(2, 2)) // 8x8
            .add(Conv2d::new(48, 96, 3, 1, 1))
            .add(ReLU)
            .add(Conv2d::new(96, 64, 3, 1, 1))
            .add(ReLU)
            .add(Conv2d::new(64, 64, 3, 1, 1))
            .add(ReLU)
            .add(MaxPool2d::new(2, 2)) // 4x4
            .add(Flatten)
            .add(Dropout::new(0.5))
            .add(Linear::new(64 * (hw / 8) * (hw / 8), 512))
            .add(ReLU)
            .add(Dropout::new(0.5))
            .add(Linear::new(512, 256))
            .add(ReLU)
            .add(Linear::new(256, classes));
        AlexNet { net, classes, batch, input: (c_in, hw, hw) }
    }
}

impl Module for AlexNet {
    fn forward(&self, x: &Tensor) -> Tensor {
        self.net.forward(x)
    }
    fn parameters(&self) -> Vec<Tensor> {
        self.net.parameters()
    }
    fn set_training(&mut self, training: bool) {
        self.net.set_training(training);
    }
    fn name(&self) -> &'static str {
        "AlexNet"
    }
}

impl BenchModel for AlexNet {
    fn name(&self) -> &'static str {
        "alexnet"
    }
    fn parameters(&self) -> Vec<Tensor> {
        self.net.parameters()
    }
    fn loss(&self, batch: &Batch) -> Tensor {
        image_loss(&self.net, batch)
    }
    fn make_batch(&self, seed: u64) -> Batch {
        let (c, h, w) = self.input;
        image_batch(seed, self.batch, c, h, w, self.classes)
    }
    fn set_training(&mut self, training: bool) {
        self.net.set_training(training);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModuleExt;

    #[test]
    fn forward_shape_and_backward() {
        crate::rng::manual_seed(0);
        let mut m = AlexNet::new(3, 32, 10, 2);
        BenchModel::set_training(&mut m, true);
        let batch = m.make_batch(1);
        let loss = BenchModel::loss(&m, &batch);
        assert_eq!(loss.shape(), &[] as &[usize]);
        assert!(loss.item().is_finite());
        loss.backward();
        let with_grad = BenchModel::parameters(&m).iter().filter(|p| p.grad().is_some()).count();
        assert_eq!(with_grad, BenchModel::parameters(&m).len());
    }

    #[test]
    fn parameter_count_in_expected_range() {
        crate::rng::manual_seed(0);
        let m = AlexNet::table1();
        let n = Module::parameters(&m).iter().map(|p| p.numel()).sum::<usize>();
        // Scaled model: roughly 0.8M-2M params.
        assert!((500_000..3_000_000).contains(&n), "params={n}");
        let _ = m.num_parameters();
    }
}
