//! Optimizers — "just Python programs" (§4.1): they read `.grad` and apply
//! in-place updates under `no_grad`, exactly the loop a user could write.
//!
//! The updates themselves route through the fused dispatcher kernels
//! (`fused:sgd_step` / `fused:adam_step`): one pass over each param +
//! state buffer instead of the 2–7 separately dispatched `mul_scalar_` /
//! `axpy_` / `sqrt` / `div` passes of the naive composition, with
//! bit-identical results (pinned by `tests/fused_parity.rs`).

use std::collections::BTreeMap;

use crate::autograd::no_grad;
use crate::dispatch::{self, Param};
use crate::tensor::Tensor;
use crate::{torsk_assert, torsk_bail};

/// Serializable optimizer state: step count, hyper-parameters, and the
/// per-parameter state tensors (momentum/Adam moments) — the optimizer
/// half of a training checkpoint (`torch.optim.Optimizer.state_dict`).
///
/// Tensor values are *copies* (checkpoint semantics, like
/// [`crate::nn::Module::state_dict`]): later fused in-place `step`s do not
/// mutate a saved state dict. Keys are positional (`velocity.3`, `m.0`),
/// matching the optimizer's parameter order; a parameter whose state was
/// never created (no grad seen yet) is simply absent.
pub struct OptimStateDict {
    /// Which optimizer produced this ("sgd", "adam") — load is strict.
    pub kind: String,
    /// Step count (Adam's bias-correction `t`; 0 for SGD).
    pub step: u64,
    /// Scalar hyper-parameters by name (lr, momentum, betas, ...).
    pub hypers: BTreeMap<String, f32>,
    /// Per-parameter state tensors by positional key.
    pub tensors: BTreeMap<String, Tensor>,
}

/// Deep-copy a state tensor for checkpointing (contiguous, detached, own
/// storage — `.contiguous()` alone would alias an already-dense tensor).
fn snapshot(t: &Tensor) -> Tensor {
    let copy = Tensor::empty(t.shape(), t.dtype(), t.device());
    no_grad(|| copy.copy_(&t.detach().contiguous()));
    copy
}

/// Restore one positional state slot from a state dict: absent key →
/// `None`, present key → fresh buffer shaped like `param` (the fused
/// in-place step kernels then mutate that private buffer, never the
/// checkpoint's).
fn restore_slot(sd: &OptimStateDict, key: &str, param: &Tensor) -> Option<Tensor> {
    sd.tensors.get(key).map(|src| {
        torsk_assert!(
            src.shape() == param.shape(),
            "optimizer load_state_dict: shape mismatch for '{key}': {:?} vs param {:?}",
            src.shape(),
            param.shape()
        );
        snapshot(src)
    })
}

/// The optimizer interface (`torch.optim.Optimizer`).
pub trait Optimizer {
    /// Apply one update from the accumulated gradients.
    fn step(&mut self);
    /// Clear gradients (`optimizer.zero_grad()`).
    fn zero_grad(&mut self);
    /// The parameters being optimized.
    fn parameters(&self) -> &[Tensor];
    /// Current learning rate.
    fn lr(&self) -> f32;
    /// Set the learning rate (schedulers are user code too).
    fn set_lr(&mut self, lr: f32);
    /// Snapshot all optimizer state for checkpointing.
    fn state_dict(&self) -> OptimStateDict;
    /// Restore state saved by [`Optimizer::state_dict`]. Strict: the kind
    /// must match, every stored tensor must fit its parameter, and
    /// unexpected keys are errors.
    fn load_state_dict(&mut self, sd: &OptimStateDict);
}

/// Strict-key check shared by the optimizers: every stored tensor key must
/// be one this optimizer would itself produce.
fn check_no_unexpected_keys(sd: &OptimStateDict, prefixes: &[&str], n_params: usize) {
    for key in sd.tensors.keys() {
        let ok = prefixes.iter().any(|p| {
            key.strip_prefix(p)
                .and_then(|rest| rest.strip_prefix('.'))
                .and_then(|idx| idx.parse::<usize>().ok())
                .is_some_and(|i| i < n_params)
        });
        torsk_assert!(ok, "optimizer load_state_dict: unexpected key '{key}'");
    }
}

/// SGD with optional momentum and weight decay.
pub struct Sgd {
    params: Vec<Tensor>,
    pub learning_rate: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Vec<Option<Tensor>>,
}

impl Sgd {
    pub fn new(params: Vec<Tensor>, lr: f32) -> Sgd {
        let n = params.len();
        Sgd { params, learning_rate: lr, momentum: 0.0, weight_decay: 0.0, velocity: vec![None; n] }
    }

    pub fn with_momentum(mut self, m: f32) -> Sgd {
        self.momentum = m;
        self
    }

    pub fn with_weight_decay(mut self, wd: f32) -> Sgd {
        self.weight_decay = wd;
        self
    }
}

impl Optimizer for Sgd {
    fn step(&mut self) {
        no_grad(|| {
            let params = [
                Param::F32(self.learning_rate),
                Param::F32(self.momentum),
                Param::F32(self.weight_decay),
            ];
            for (i, p) in self.params.iter().enumerate() {
                let Some(g) = p.grad() else { continue };
                let g = g.contiguous();
                if self.momentum != 0.0 {
                    // Zero-initialized velocity reproduces the classic
                    // first step (`v = g`) exactly: 0*mu + g == g.
                    let v = self.velocity[i].get_or_insert_with(|| p.zeros_like()).clone();
                    dispatch::call("fused:sgd_step", &[p, &g, &v], &params);
                } else {
                    dispatch::call("fused:sgd_step", &[p, &g], &params);
                }
            }
        });
    }

    fn zero_grad(&mut self) {
        for p in &self.params {
            p.set_grad(None);
        }
    }

    fn parameters(&self) -> &[Tensor] {
        &self.params
    }

    fn lr(&self) -> f32 {
        self.learning_rate
    }

    fn set_lr(&mut self, lr: f32) {
        self.learning_rate = lr;
    }

    fn state_dict(&self) -> OptimStateDict {
        let mut hypers = BTreeMap::new();
        hypers.insert("lr".to_string(), self.learning_rate);
        hypers.insert("momentum".to_string(), self.momentum);
        hypers.insert("weight_decay".to_string(), self.weight_decay);
        let mut tensors = BTreeMap::new();
        for (i, v) in self.velocity.iter().enumerate() {
            if let Some(v) = v {
                tensors.insert(format!("velocity.{i}"), snapshot(v));
            }
        }
        OptimStateDict { kind: "sgd".to_string(), step: 0, hypers, tensors }
    }

    fn load_state_dict(&mut self, sd: &OptimStateDict) {
        if sd.kind != "sgd" {
            torsk_bail!("Sgd::load_state_dict: state dict is for '{}'", sd.kind);
        }
        check_no_unexpected_keys(sd, &["velocity"], self.params.len());
        if let Some(&lr) = sd.hypers.get("lr") {
            self.learning_rate = lr;
        }
        if let Some(&m) = sd.hypers.get("momentum") {
            self.momentum = m;
        }
        if let Some(&wd) = sd.hypers.get("weight_decay") {
            self.weight_decay = wd;
        }
        for (i, p) in self.params.iter().enumerate() {
            self.velocity[i] = restore_slot(sd, &format!("velocity.{i}"), p);
        }
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    params: Vec<Tensor>,
    pub learning_rate: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<Option<Tensor>>,
    v: Vec<Option<Tensor>>,
    t: u64,
}

impl Adam {
    pub fn new(params: Vec<Tensor>, lr: f32) -> Adam {
        let n = params.len();
        Adam {
            params,
            learning_rate: lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: vec![None; n],
            v: vec![None; n],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self) {
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        no_grad(|| {
            let params = [
                Param::F32(self.learning_rate),
                Param::F32(self.beta1),
                Param::F32(self.beta2),
                Param::F32(self.eps),
                Param::F32(self.weight_decay),
                Param::F32(bc1),
                Param::F32(bc2),
            ];
            for (i, p) in self.params.iter().enumerate() {
                let Some(g) = p.grad() else { continue };
                let g = g.contiguous();
                let m = self.m[i].get_or_insert_with(|| p.zeros_like()).clone();
                let v = self.v[i].get_or_insert_with(|| p.zeros_like()).clone();
                // One fused pass: m/v moment updates, bias correction and
                // the parameter step — no intermediate tensors at all.
                dispatch::call("fused:adam_step", &[p, &g, &m, &v], &params);
            }
        });
    }

    fn zero_grad(&mut self) {
        for p in &self.params {
            p.set_grad(None);
        }
    }

    fn parameters(&self) -> &[Tensor] {
        &self.params
    }

    fn lr(&self) -> f32 {
        self.learning_rate
    }

    fn set_lr(&mut self, lr: f32) {
        self.learning_rate = lr;
    }

    fn state_dict(&self) -> OptimStateDict {
        let mut hypers = BTreeMap::new();
        hypers.insert("lr".to_string(), self.learning_rate);
        hypers.insert("beta1".to_string(), self.beta1);
        hypers.insert("beta2".to_string(), self.beta2);
        hypers.insert("eps".to_string(), self.eps);
        hypers.insert("weight_decay".to_string(), self.weight_decay);
        let mut tensors = BTreeMap::new();
        for (i, m) in self.m.iter().enumerate() {
            if let Some(m) = m {
                tensors.insert(format!("m.{i}"), snapshot(m));
            }
        }
        for (i, v) in self.v.iter().enumerate() {
            if let Some(v) = v {
                tensors.insert(format!("v.{i}"), snapshot(v));
            }
        }
        OptimStateDict { kind: "adam".to_string(), step: self.t, hypers, tensors }
    }

    fn load_state_dict(&mut self, sd: &OptimStateDict) {
        if sd.kind != "adam" {
            torsk_bail!("Adam::load_state_dict: state dict is for '{}'", sd.kind);
        }
        check_no_unexpected_keys(sd, &["m", "v"], self.params.len());
        if let Some(&lr) = sd.hypers.get("lr") {
            self.learning_rate = lr;
        }
        if let Some(&b1) = sd.hypers.get("beta1") {
            self.beta1 = b1;
        }
        if let Some(&b2) = sd.hypers.get("beta2") {
            self.beta2 = b2;
        }
        if let Some(&eps) = sd.hypers.get("eps") {
            self.eps = eps;
        }
        if let Some(&wd) = sd.hypers.get("weight_decay") {
            self.weight_decay = wd;
        }
        self.t = sd.step;
        for (i, p) in self.params.iter().enumerate() {
            self.m[i] = restore_slot(sd, &format!("m.{i}"), p);
            self.v[i] = restore_slot(sd, &format!("v.{i}"), p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    /// Minimize f(w) = (w - 3)^2 and check convergence.
    fn quadratic_converges(mut opt: impl Optimizer, w: Tensor, steps: usize) -> f32 {
        for _ in 0..steps {
            opt.zero_grad();
            let diff = ops::add_scalar(&w, -3.0);
            let loss = ops::mul(&diff, &diff).sum();
            loss.backward();
            opt.step();
        }
        w.to_vec::<f32>()[0]
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let opt = Sgd::new(vec![w.clone()], 0.1);
        let final_w = quadratic_converges(opt, w, 100);
        assert!((final_w - 3.0).abs() < 1e-3, "w={final_w}");
    }

    #[test]
    fn sgd_momentum_converges_faster_on_illconditioned() {
        // f(w) = w0^2 + 100*w1^2 style: momentum should reach lower loss
        // than plain SGD for the same step count and lr.
        let run = |momentum: f32| -> f32 {
            let w = Tensor::from_slice(&[5.0f32, 5.0]).requires_grad(true);
            let scale = Tensor::from_slice(&[1.0f32, 25.0]);
            let mut opt = Sgd::new(vec![w.clone()], 0.01).with_momentum(momentum);
            for _ in 0..60 {
                opt.zero_grad();
                let loss = ops::mul(&scale, &ops::mul(&w, &w)).sum();
                loss.backward();
                opt.step();
            }
            ops::mul(&scale, &ops::mul(&w.detach(), &w.detach())).sum().item()
        };
        let plain = run(0.0);
        let mom = run(0.9);
        assert!(mom < plain, "momentum {mom} vs plain {plain}");
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let w = Tensor::from_slice(&[1.0f32]).requires_grad(true);
        let mut opt = Sgd::new(vec![w.clone()], 0.1).with_weight_decay(0.5);
        // Zero-gradient loss: only decay acts.
        opt.zero_grad();
        w.set_grad(Some(Tensor::zeros(&[1])));
        opt.step();
        assert!((w.to_vec::<f32>()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let opt = Adam::new(vec![w.clone()], 0.2);
        let final_w = quadratic_converges(opt, w, 200);
        assert!((final_w - 3.0).abs() < 1e-2, "w={final_w}");
    }

    #[test]
    fn adam_first_step_magnitude_is_lr() {
        // Bias correction => first update ≈ lr * sign(g).
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let mut opt = Adam::new(vec![w.clone()], 0.1);
        w.set_grad(Some(Tensor::from_slice(&[42.0f32])));
        opt.step();
        assert!((w.to_vec::<f32>()[0] + 0.1).abs() < 1e-4);
    }

    #[test]
    fn step_skips_params_without_grad() {
        let w = Tensor::from_slice(&[1.0f32]).requires_grad(true);
        let mut opt = Sgd::new(vec![w.clone()], 0.1);
        opt.step(); // no grad set
        assert_eq!(w.to_vec::<f32>(), vec![1.0]);
    }

    #[test]
    fn state_dict_at_step_zero_is_empty() {
        let w = Tensor::from_slice(&[1.0f32, 2.0]).requires_grad(true);
        let sgd = Sgd::new(vec![w.clone()], 0.1).with_momentum(0.9);
        let sd = sgd.state_dict();
        assert_eq!(sd.kind, "sgd");
        assert_eq!(sd.step, 0);
        assert!(sd.tensors.is_empty(), "no step taken => no velocity");
        let adam = Adam::new(vec![w], 0.1);
        let sd = adam.state_dict();
        assert_eq!(sd.kind, "adam");
        assert_eq!(sd.step, 0);
        assert!(sd.tensors.is_empty());
    }

    #[test]
    fn state_dict_is_a_deep_copy() {
        let w = Tensor::from_slice(&[1.0f32]).requires_grad(true);
        let mut opt = Sgd::new(vec![w.clone()], 0.1).with_momentum(0.9);
        w.set_grad(Some(Tensor::from_slice(&[1.0f32])));
        opt.step();
        let sd = opt.state_dict();
        let before = sd.tensors["velocity.0"].to_vec::<f32>();
        // More steps mutate the live velocity in place via the fused kernel;
        // the snapshot must not move.
        w.set_grad(Some(Tensor::from_slice(&[1.0f32])));
        opt.step();
        assert_eq!(sd.tensors["velocity.0"].to_vec::<f32>(), before);
        assert_ne!(opt.velocity[0].as_ref().unwrap().to_vec::<f32>(), before);
    }

    /// Run `steps` optimizer steps of f(w) = (w - 3)^2, returning bit
    /// patterns of the final weights.
    fn train_bits(opt: &mut dyn Optimizer, w: &Tensor, steps: usize) -> Vec<u32> {
        for _ in 0..steps {
            opt.zero_grad();
            let diff = ops::add_scalar(w, -3.0);
            let loss = ops::mul(&diff, &diff).sum();
            loss.backward();
            opt.step();
        }
        w.to_vec::<f32>().iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn sgd_resume_from_state_dict_is_bitwise() {
        // Uninterrupted: 10 steps.
        let w_full = Tensor::from_slice(&[0.0f32, 5.0]).requires_grad(true);
        let mut full = Sgd::new(vec![w_full.clone()], 0.05).with_momentum(0.9);
        let expected = train_bits(&mut full, &w_full, 10);

        // Interrupted: 6 steps, checkpoint, rebuild everything, 4 more.
        let w = Tensor::from_slice(&[0.0f32, 5.0]).requires_grad(true);
        let mut opt = Sgd::new(vec![w.clone()], 0.05).with_momentum(0.9);
        train_bits(&mut opt, &w, 6);
        let sd = opt.state_dict();
        let mid: Vec<f32> = w.to_vec::<f32>();

        let w2 = Tensor::from_slice(&mid).requires_grad(true);
        let mut opt2 = Sgd::new(vec![w2.clone()], 0.05).with_momentum(0.9);
        opt2.load_state_dict(&sd);
        let resumed = train_bits(&mut opt2, &w2, 4);
        assert_eq!(expected, resumed, "resume must be bitwise identical");
    }

    #[test]
    fn adam_resume_from_state_dict_is_bitwise() {
        let w_full = Tensor::from_slice(&[0.0f32, 5.0]).requires_grad(true);
        let mut full = Adam::new(vec![w_full.clone()], 0.1);
        let expected = train_bits(&mut full, &w_full, 10);

        let w = Tensor::from_slice(&[0.0f32, 5.0]).requires_grad(true);
        let mut opt = Adam::new(vec![w.clone()], 0.1);
        train_bits(&mut opt, &w, 6);
        let sd = opt.state_dict();
        assert_eq!(sd.step, 6, "Adam step count rides along for bias correction");
        let mid: Vec<f32> = w.to_vec::<f32>();

        let w2 = Tensor::from_slice(&mid).requires_grad(true);
        let mut opt2 = Adam::new(vec![w2.clone()], 0.1);
        opt2.load_state_dict(&sd);
        let resumed = train_bits(&mut opt2, &w2, 4);
        assert_eq!(expected, resumed, "resume must be bitwise identical");
    }

    #[test]
    #[should_panic(expected = "state dict is for 'adam'")]
    fn load_rejects_kind_mismatch() {
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let sd = Adam::new(vec![w.clone()], 0.1).state_dict();
        Sgd::new(vec![w], 0.1).load_state_dict(&sd);
    }

    #[test]
    #[should_panic(expected = "unexpected key 'velocity.9'")]
    fn load_rejects_unexpected_keys() {
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let mut sd = Sgd::new(vec![w.clone()], 0.1).state_dict();
        sd.tensors.insert("velocity.9".to_string(), Tensor::zeros(&[1]));
        Sgd::new(vec![w], 0.1).load_state_dict(&sd);
    }

    #[test]
    #[should_panic(expected = "shape mismatch for 'velocity.0'")]
    fn load_rejects_shape_mismatch() {
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let mut sd = Sgd::new(vec![w.clone()], 0.1).state_dict();
        sd.tensors.insert("velocity.0".to_string(), Tensor::zeros(&[3]));
        Sgd::new(vec![w], 0.1).load_state_dict(&sd);
    }
}
