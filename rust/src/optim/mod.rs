//! Optimizers — "just Python programs" (§4.1): they read `.grad` and apply
//! in-place updates under `no_grad`, exactly the loop a user could write.
//!
//! The updates themselves route through the fused dispatcher kernels
//! (`fused:sgd_step` / `fused:adam_step`): one pass over each param +
//! state buffer instead of the 2–7 separately dispatched `mul_scalar_` /
//! `axpy_` / `sqrt` / `div` passes of the naive composition, with
//! bit-identical results (pinned by `tests/fused_parity.rs`).

use crate::autograd::no_grad;
use crate::dispatch::{self, Param};
use crate::tensor::Tensor;

/// The optimizer interface (`torch.optim.Optimizer`).
pub trait Optimizer {
    /// Apply one update from the accumulated gradients.
    fn step(&mut self);
    /// Clear gradients (`optimizer.zero_grad()`).
    fn zero_grad(&mut self);
    /// The parameters being optimized.
    fn parameters(&self) -> &[Tensor];
    /// Current learning rate.
    fn lr(&self) -> f32;
    /// Set the learning rate (schedulers are user code too).
    fn set_lr(&mut self, lr: f32);
}

/// SGD with optional momentum and weight decay.
pub struct Sgd {
    params: Vec<Tensor>,
    pub learning_rate: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Vec<Option<Tensor>>,
}

impl Sgd {
    pub fn new(params: Vec<Tensor>, lr: f32) -> Sgd {
        let n = params.len();
        Sgd { params, learning_rate: lr, momentum: 0.0, weight_decay: 0.0, velocity: vec![None; n] }
    }

    pub fn with_momentum(mut self, m: f32) -> Sgd {
        self.momentum = m;
        self
    }

    pub fn with_weight_decay(mut self, wd: f32) -> Sgd {
        self.weight_decay = wd;
        self
    }
}

impl Optimizer for Sgd {
    fn step(&mut self) {
        no_grad(|| {
            let params = [
                Param::F32(self.learning_rate),
                Param::F32(self.momentum),
                Param::F32(self.weight_decay),
            ];
            for (i, p) in self.params.iter().enumerate() {
                let Some(g) = p.grad() else { continue };
                let g = g.contiguous();
                if self.momentum != 0.0 {
                    // Zero-initialized velocity reproduces the classic
                    // first step (`v = g`) exactly: 0*mu + g == g.
                    let v = self.velocity[i].get_or_insert_with(|| p.zeros_like()).clone();
                    dispatch::call("fused:sgd_step", &[p, &g, &v], &params);
                } else {
                    dispatch::call("fused:sgd_step", &[p, &g], &params);
                }
            }
        });
    }

    fn zero_grad(&mut self) {
        for p in &self.params {
            p.set_grad(None);
        }
    }

    fn parameters(&self) -> &[Tensor] {
        &self.params
    }

    fn lr(&self) -> f32 {
        self.learning_rate
    }

    fn set_lr(&mut self, lr: f32) {
        self.learning_rate = lr;
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    params: Vec<Tensor>,
    pub learning_rate: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<Option<Tensor>>,
    v: Vec<Option<Tensor>>,
    t: u64,
}

impl Adam {
    pub fn new(params: Vec<Tensor>, lr: f32) -> Adam {
        let n = params.len();
        Adam {
            params,
            learning_rate: lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: vec![None; n],
            v: vec![None; n],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self) {
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        no_grad(|| {
            let params = [
                Param::F32(self.learning_rate),
                Param::F32(self.beta1),
                Param::F32(self.beta2),
                Param::F32(self.eps),
                Param::F32(self.weight_decay),
                Param::F32(bc1),
                Param::F32(bc2),
            ];
            for (i, p) in self.params.iter().enumerate() {
                let Some(g) = p.grad() else { continue };
                let g = g.contiguous();
                let m = self.m[i].get_or_insert_with(|| p.zeros_like()).clone();
                let v = self.v[i].get_or_insert_with(|| p.zeros_like()).clone();
                // One fused pass: m/v moment updates, bias correction and
                // the parameter step — no intermediate tensors at all.
                dispatch::call("fused:adam_step", &[p, &g, &m, &v], &params);
            }
        });
    }

    fn zero_grad(&mut self) {
        for p in &self.params {
            p.set_grad(None);
        }
    }

    fn parameters(&self) -> &[Tensor] {
        &self.params
    }

    fn lr(&self) -> f32 {
        self.learning_rate
    }

    fn set_lr(&mut self, lr: f32) {
        self.learning_rate = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    /// Minimize f(w) = (w - 3)^2 and check convergence.
    fn quadratic_converges(mut opt: impl Optimizer, w: Tensor, steps: usize) -> f32 {
        for _ in 0..steps {
            opt.zero_grad();
            let diff = ops::add_scalar(&w, -3.0);
            let loss = ops::mul(&diff, &diff).sum();
            loss.backward();
            opt.step();
        }
        w.to_vec::<f32>()[0]
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let opt = Sgd::new(vec![w.clone()], 0.1);
        let final_w = quadratic_converges(opt, w, 100);
        assert!((final_w - 3.0).abs() < 1e-3, "w={final_w}");
    }

    #[test]
    fn sgd_momentum_converges_faster_on_illconditioned() {
        // f(w) = w0^2 + 100*w1^2 style: momentum should reach lower loss
        // than plain SGD for the same step count and lr.
        let run = |momentum: f32| -> f32 {
            let w = Tensor::from_slice(&[5.0f32, 5.0]).requires_grad(true);
            let scale = Tensor::from_slice(&[1.0f32, 25.0]);
            let mut opt = Sgd::new(vec![w.clone()], 0.01).with_momentum(momentum);
            for _ in 0..60 {
                opt.zero_grad();
                let loss = ops::mul(&scale, &ops::mul(&w, &w)).sum();
                loss.backward();
                opt.step();
            }
            ops::mul(&scale, &ops::mul(&w.detach(), &w.detach())).sum().item()
        };
        let plain = run(0.0);
        let mom = run(0.9);
        assert!(mom < plain, "momentum {mom} vs plain {plain}");
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let w = Tensor::from_slice(&[1.0f32]).requires_grad(true);
        let mut opt = Sgd::new(vec![w.clone()], 0.1).with_weight_decay(0.5);
        // Zero-gradient loss: only decay acts.
        opt.zero_grad();
        w.set_grad(Some(Tensor::zeros(&[1])));
        opt.step();
        assert!((w.to_vec::<f32>()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let opt = Adam::new(vec![w.clone()], 0.2);
        let final_w = quadratic_converges(opt, w, 200);
        assert!((final_w - 3.0).abs() < 1e-2, "w={final_w}");
    }

    #[test]
    fn adam_first_step_magnitude_is_lr() {
        // Bias correction => first update ≈ lr * sign(g).
        let w = Tensor::from_slice(&[0.0f32]).requires_grad(true);
        let mut opt = Adam::new(vec![w.clone()], 0.1);
        w.set_grad(Some(Tensor::from_slice(&[42.0f32])));
        opt.step();
        assert!((w.to_vec::<f32>()[0] + 0.1).abs() < 1e-4);
    }

    #[test]
    fn step_skips_params_without_grad() {
        let w = Tensor::from_slice(&[1.0f32]).requires_grad(true);
        let mut opt = Sgd::new(vec![w.clone()], 0.1);
        opt.step(); // no grad set
        assert_eq!(w.to_vec::<f32>(), vec![1.0]);
    }
}
