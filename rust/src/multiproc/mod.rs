//! Multiprocessing with shared-memory tensors (§5.4).
//!
//! Python's GIL forces parallelism across *processes*; the paper's
//! `torch.multiprocessing` makes that cheap by moving tensor data to
//! shared memory instead of serializing it through a pipe — "a programming
//! model which more closely resembles regular threaded programs".
//!
//! torsk reproduces the machinery:
//! - [`SharedRegion`] — a file-backed `mmap(MAP_SHARED)` region (under
//!   `/dev/shm` by default) usable across `fork` *and* independent
//!   processes;
//! - [`SharedTensor`] — a tensor whose storage lives in a shared region
//!   (self-describing header, so another process can `open` it by path);
//!   `.tensor()` is a zero-copy view, like `torch.Tensor.share_memory_()`;
//! - [`fork_workers`] — spawn N child processes running a closure (the
//!   `torch.multiprocessing.spawn` analog);
//! - [`allreduce_mean`] / [`ShmLock`] / [`ShmBarrier`] — the "all-reduce
//!   style primitives" users build data-parallel training from;
//! - Hogwild (lock-free shared-parameter SGD, §5.4's closing example) is
//!   exercised in `examples/hogwild.rs` and the integration tests.
//!
//! # Processes vs. the thread-based `data` loader
//!
//! The paper reaches for worker *processes* because Python's GIL makes
//! threads useless for CPU-bound data preparation; shared memory then
//! exists to make inter-process tensor transport cheap. torsk has no GIL,
//! so the [`crate::data::DataLoader`] prefetches with plain threads and
//! hands batches over a channel — use *this* module when you genuinely
//! need separate address spaces: Hogwild-style shared parameters,
//! multi-process data parallelism ([`allreduce_mean`]), or surviving a
//! worker crash. The two compose: `examples/hogwild.rs` runs a
//! `DataLoader` inside each forked worker.
//!
//! Fork safety: [`fork_workers`] forks without `exec`, so children start
//! with only the calling thread. Nothing inherited may be relied on —
//! not the kernel pool, not stream workers, not live prefetch threads.
//! Threads the child spawns itself (e.g. its own loader workers) are
//! fine. Keep the parent single-threaded-quiescent at fork time (no
//! in-flight kernels), or a lock held by a non-forked thread can deadlock
//! the child.

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::alloc::{AllocStats, Allocator, Block, StreamId};
use crate::error::{Result, TorskError};
use crate::tensor::{DType, Tensor};

/// Magic bytes identifying a torsk shared tensor file.
const MAGIC: u32 = 0x7052_534B; // "pRSK"
/// Header layout: magic, dtype, ndim, dims[8], lock, barrier{count,sense},
/// all u64-aligned u32s padded to 64 bytes * 2.
const HEADER_BYTES: usize = 128;
const MAX_DIMS: usize = 8;

/// A shared, file-backed memory mapping.
pub struct SharedRegion {
    ptr: *mut u8,
    len: usize,
    path: PathBuf,
    owner: bool,
}

// SAFETY: the mapping is plain shared memory valid for the region's
// lifetime; cross-process readers synchronize through the barrier
// protocol, not through &self, so moving the handle across threads is fine.
unsafe impl Send for SharedRegion {}
// SAFETY: see Send above — &self only exposes the raw mapping, and all
// concurrent access is mediated by the barrier/reduce protocol.
unsafe impl Sync for SharedRegion {}

impl SharedRegion {
    /// Create (or overwrite) a shared region of `len` bytes at `path`.
    pub fn create(path: &Path, len: usize) -> Result<SharedRegion> {
        let cpath = std::ffi::CString::new(path.as_os_str().to_str().unwrap()).unwrap();
        // SAFETY: standard open/ftruncate/mmap sequence.
        unsafe {
            let fd = libc::open(cpath.as_ptr(), libc::O_RDWR | libc::O_CREAT, 0o600);
            if fd < 0 {
                return Err(TorskError::Multiproc(format!("open {}", path.display())));
            }
            if libc::ftruncate(fd, len as libc::off_t) != 0 {
                libc::close(fd);
                return Err(TorskError::Multiproc("ftruncate failed".into()));
            }
            let ptr = libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            libc::close(fd);
            if ptr == libc::MAP_FAILED {
                return Err(TorskError::Multiproc("mmap failed".into()));
            }
            Ok(SharedRegion { ptr: ptr as *mut u8, len, path: path.to_path_buf(), owner: true })
        }
    }

    /// Map an existing shared region.
    pub fn open(path: &Path) -> Result<SharedRegion> {
        let cpath = std::ffi::CString::new(path.as_os_str().to_str().unwrap()).unwrap();
        // SAFETY: standard open/fstat/mmap sequence; every libc return
        // value is checked before use.
        unsafe {
            let fd = libc::open(cpath.as_ptr(), libc::O_RDWR, 0);
            if fd < 0 {
                return Err(TorskError::Multiproc(format!("open {}", path.display())));
            }
            let mut st: libc::stat = std::mem::zeroed();
            if libc::fstat(fd, &mut st) != 0 {
                libc::close(fd);
                return Err(TorskError::Multiproc("fstat failed".into()));
            }
            let len = st.st_size as usize;
            let ptr = libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            libc::close(fd);
            if ptr == libc::MAP_FAILED {
                return Err(TorskError::Multiproc("mmap failed".into()));
            }
            Ok(SharedRegion { ptr: ptr as *mut u8, len, path: path.to_path_buf(), owner: false })
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn header_u32(&self, idx: usize) -> &AtomicU32 {
        debug_assert!(idx * 4 < HEADER_BYTES);
        // SAFETY: header region is within the mapping and properly aligned.
        unsafe { &*(self.ptr.add(idx * 4) as *const AtomicU32) }
    }

    fn data_ptr(&self) -> *mut u8 {
        // SAFETY: len > HEADER_BYTES enforced at creation.
        unsafe { self.ptr.add(HEADER_BYTES) }
    }

    /// Remove the backing file (call once, from the owner).
    pub fn unlink(&self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for SharedRegion {
    fn drop(&mut self) {
        // SAFETY: ptr/len from our own mmap.
        unsafe {
            libc::munmap(self.ptr as *mut libc::c_void, self.len);
        }
        let _ = self.owner; // files are unlinked explicitly
    }
}

/// Allocator facade that keeps a shared region alive and never frees —
/// lets shared memory masquerade as regular tensor storage.
struct RegionAllocator {
    _region: Arc<SharedRegion>,
}

impl Allocator for RegionAllocator {
    fn allocate(&self, _bytes: usize, _stream: StreamId) -> Block {
        crate::torsk_bail!("RegionAllocator cannot allocate");
    }
    fn deallocate(&self, _block: Block) {}
    fn stats(&self) -> AllocStats {
        AllocStats::default()
    }
    fn reset_stats(&self) {}
}

/// A tensor living in cross-process shared memory.
pub struct SharedTensor {
    region: Arc<SharedRegion>,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl SharedTensor {
    /// Create a shared f32/i64 tensor at `path`.
    pub fn create(path: &Path, shape: &[usize], dtype: DType) -> Result<SharedTensor> {
        let n: usize = shape.iter().product();
        crate::torsk_assert!(shape.len() <= MAX_DIMS, "too many dims");
        let region = SharedRegion::create(path, HEADER_BYTES + n * dtype.size())?;
        region.header_u32(0).store(MAGIC, Ordering::SeqCst);
        region.header_u32(1).store(
            match dtype {
                DType::F32 => 0,
                DType::I64 => 1,
                DType::F64 => 2,
            },
            Ordering::SeqCst,
        );
        region.header_u32(2).store(shape.len() as u32, Ordering::SeqCst);
        for (i, &d) in shape.iter().enumerate() {
            region.header_u32(3 + i).store(d as u32, Ordering::SeqCst);
        }
        Ok(SharedTensor { region: Arc::new(region), shape: shape.to_vec(), dtype })
    }

    /// Open a shared tensor created by another process.
    pub fn open(path: &Path) -> Result<SharedTensor> {
        let region = SharedRegion::open(path)?;
        if region.header_u32(0).load(Ordering::SeqCst) != MAGIC {
            return Err(TorskError::Multiproc("bad magic in shared tensor".into()));
        }
        let dtype = match region.header_u32(1).load(Ordering::SeqCst) {
            0 => DType::F32,
            1 => DType::I64,
            2 => DType::F64,
            _ => return Err(TorskError::Multiproc("bad dtype".into())),
        };
        let ndim = region.header_u32(2).load(Ordering::SeqCst) as usize;
        let shape: Vec<usize> =
            (0..ndim).map(|i| region.header_u32(3 + i).load(Ordering::SeqCst) as usize).collect();
        Ok(SharedTensor { region: Arc::new(region), shape, dtype })
    }

    /// Zero-copy tensor view over the shared data (like `share_memory_()`;
    /// "objects on both sides only describe how to interpret a memory
    /// region which is shared among them", §4.2).
    pub fn tensor(&self) -> Tensor {
        let n: usize = self.shape.iter().product();
        let nbytes = n * self.dtype.size();
        let block = Block {
            ptr: std::ptr::NonNull::new(self.region.data_ptr()).unwrap(),
            size: nbytes,
            requested: nbytes,
            stream: StreamId::HOST,
            root: false,
        };
        let alloc: Arc<dyn Allocator> = Arc::new(RegionAllocator { _region: self.region.clone() });
        Tensor::from_external_block(block, nbytes, self.shape.clone(), self.dtype, alloc)
    }

    /// Copy data from a regular tensor into shared memory.
    pub fn copy_from(&self, t: &Tensor) {
        crate::torsk_assert!(t.shape() == self.shape, "shape mismatch");
        let view = self.tensor();
        view.copy_(&t.to_cpu().contiguous());
    }

    /// Spin-lock guarding the region (slot 12).
    pub fn lock(&self) -> ShmLock<'_> {
        ShmLock::acquire(self.region.header_u32(12))
    }

    /// Remove the backing file.
    pub fn unlink(&self) {
        self.region.unlink();
    }

    pub fn path(&self) -> &Path {
        self.region.path()
    }
}

/// Simple cross-process spin lock living in a shared header word.
pub struct ShmLock<'a> {
    word: &'a AtomicU32,
}

impl<'a> ShmLock<'a> {
    fn acquire(word: &'a AtomicU32) -> ShmLock<'a> {
        while word.compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed).is_err() {
            std::hint::spin_loop();
        }
        ShmLock { word }
    }
}

impl Drop for ShmLock<'_> {
    fn drop(&mut self) {
        self.word.store(0, Ordering::Release);
    }
}

/// Sense-reversing barrier in shared memory (slots 13=count, 14=sense).
pub struct ShmBarrier {
    region: Arc<SharedRegion>,
    parties: u32,
}

impl ShmBarrier {
    /// Attach a barrier to a shared tensor's region.
    pub fn on(tensor: &SharedTensor, parties: u32) -> ShmBarrier {
        ShmBarrier { region: tensor.region.clone(), parties }
    }

    /// Wait until all parties arrive.
    pub fn wait(&self) {
        let count = self.region.header_u32(13);
        let sense = self.region.header_u32(14);
        let my_sense = sense.load(Ordering::Acquire);
        let arrived = count.fetch_add(1, Ordering::AcqRel) + 1;
        if arrived == self.parties {
            count.store(0, Ordering::Release);
            sense.store(my_sense ^ 1, Ordering::Release);
        } else {
            while sense.load(Ordering::Acquire) == my_sense {
                std::hint::spin_loop();
            }
        }
    }
}

/// How a forked worker terminated, decoded from its `waitpid` status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankExit {
    /// Killed by a signal (SIGKILL, SIGSEGV, OOM-killer's SIGKILL, ...).
    Signaled(i32),
    /// Exited voluntarily with a non-zero status code (a panicking worker
    /// `_exit`s with 101).
    Exited(i32),
    /// `waitpid` reported a status that is neither an exit nor a signal
    /// (e.g. the child is stopped, not dead).
    Stopped,
    /// `waitpid` itself failed, so the child's fate is unknown.
    WaitFailed,
}

impl fmt::Display for RankExit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RankExit::Signaled(sig) => write!(f, "killed by signal {sig}"),
            RankExit::Exited(code) => write!(f, "exited with status {code}"),
            RankExit::Stopped => write!(f, "stopped without exiting"),
            RankExit::WaitFailed => write!(f, "waitpid failed"),
        }
    }
}

/// One failed worker: which rank, which pid, and how it died. Carried by
/// [`TorskError::Workers`] so callers can react per rank (retry the rank,
/// map a signal to an infra problem) instead of parsing a joined string.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankFailure {
    /// The worker's rank in `0..n`.
    pub rank: usize,
    /// The forked process id.
    pub pid: i32,
    /// How it terminated.
    pub exit: RankExit,
}

impl fmt::Display for RankFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rank {} (pid {}): {}", self.rank, self.pid, self.exit)
    }
}

/// Fork `n` worker processes running `f(rank)`; returns once all exit.
/// Any child that does not exit cleanly with status 0 is reported in a
/// typed [`TorskError::Workers`] naming every failed rank, its pid, and
/// its [`RankExit`] — the parent always reaps all `n` children first, so
/// a crashed rank can neither hang the parent nor leak zombies.
///
/// Note: `fork` without `exec` — children must not rely on threads from
/// the parent (stream workers, kernel pool) and should stick to compute +
/// shared memory, like the paper's data-loader workers.
pub fn fork_workers(n: usize, f: impl Fn(usize)) -> Result<()> {
    let mut pids = Vec::with_capacity(n);
    for rank in 0..n {
        // SAFETY: standard fork/waitpid usage.
        let pid = unsafe { libc::fork() };
        if pid < 0 {
            return Err(TorskError::Multiproc("fork failed".into()));
        }
        if pid == 0 {
            // Child: run and _exit without unwinding into parent state.
            let code = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(rank))) {
                Ok(()) => 0,
                Err(_) => 101,
            };
            // SAFETY: _exit never returns; skipping atexit/Drop is the
            // point — the forked child must not unwind into parent state.
            unsafe { libc::_exit(code) };
        }
        pids.push(pid);
    }
    let mut failed: Vec<RankFailure> = Vec::new();
    for (rank, pid) in pids.into_iter().enumerate() {
        let mut status = 0;
        // SAFETY: plain waitpid on a pid we forked; `status` is a valid
        // out-pointer for the duration of the call.
        let r = unsafe { libc::waitpid(pid, &mut status, 0) };
        // Name each failed rank and *how* it died — a silently merged
        // partial run (one dead rank, N-1 good ones) is the worst outcome.
        let exit = if r < 0 {
            Some(RankExit::WaitFailed)
        } else if libc::WIFSIGNALED(status) {
            Some(RankExit::Signaled(libc::WTERMSIG(status)))
        } else if libc::WIFEXITED(status) {
            let code = libc::WEXITSTATUS(status);
            if code != 0 {
                Some(RankExit::Exited(code))
            } else {
                None
            }
        } else {
            Some(RankExit::Stopped)
        };
        if let Some(exit) = exit {
            failed.push(RankFailure { rank, pid, exit });
        }
    }
    if !failed.is_empty() {
        return Err(TorskError::Workers { total: n, failed });
    }
    Ok(())
}

/// All-reduce (mean) across ranks: each rank adds its `local` into the
/// shared accumulator under the lock, waits at the barrier, then reads
/// back the mean. `scratch` must be a shared tensor of the same shape,
/// zeroed before the collective.
pub fn allreduce_mean(
    local: &Tensor,
    scratch: &SharedTensor,
    barrier: &ShmBarrier,
    parties: u32,
) -> Tensor {
    {
        let _guard = scratch.lock();
        let acc = scratch.tensor();
        acc.add_(&local.to_cpu().contiguous());
    }
    barrier.wait();
    let mean = crate::ops::mul_scalar(&scratch.tensor().detach(), 1.0 / parties as f32);
    barrier.wait(); // don't let a fast rank re-zero while others read
    mean.contiguous()
}

/// Serialize-through-pipe baseline for the §5.4 bench: what transport
/// costs *without* shared memory (the `multiprocessing` default the paper
/// calls "inefficient when dealing with large arrays").
pub fn pipe_roundtrip(t: &Tensor) -> Result<Tensor> {
    let mut fds = [0i32; 2];
    // SAFETY: pipe/write/read/fork is standard POSIX.
    unsafe {
        if libc::pipe(fds.as_mut_ptr()) != 0 {
            return Err(TorskError::Multiproc("pipe failed".into()));
        }
        let data = t.to_vec::<f32>();
        let bytes = std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4);

        let pid = libc::fork();
        if pid < 0 {
            return Err(TorskError::Multiproc("fork failed".into()));
        }
        if pid == 0 {
            // Child: "serialize" (copy) the tensor into the pipe.
            libc::close(fds[0]);
            let mut written = 0usize;
            while written < bytes.len() {
                let n = libc::write(
                    fds[1],
                    bytes[written..].as_ptr() as *const libc::c_void,
                    bytes.len() - written,
                );
                if n <= 0 {
                    libc::_exit(1);
                }
                written += n as usize;
            }
            libc::close(fds[1]);
            libc::_exit(0);
        }
        libc::close(fds[1]);
        let mut buf = vec![0u8; bytes.len()];
        let mut read = 0usize;
        while read < buf.len() {
            let n = libc::read(
                fds[0],
                buf[read..].as_mut_ptr() as *mut libc::c_void,
                buf.len() - read,
            );
            if n <= 0 {
                break;
            }
            read += n as usize;
        }
        libc::close(fds[0]);
        let mut status = 0;
        libc::waitpid(pid, &mut status, 0);
        if read != buf.len() {
            return Err(TorskError::Multiproc("short pipe read".into()));
        }
        let floats = std::slice::from_raw_parts(buf.as_ptr() as *const f32, data.len()).to_vec();
        Ok(Tensor::from_vec(floats, t.shape()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = PathBuf::from("/dev/shm");
        let dir = if dir.exists() { dir } else { std::env::temp_dir() };
        dir.join(format!("torsk_test_{}_{}", std::process::id(), name))
    }

    #[test]
    fn shared_tensor_roundtrip_same_process() {
        let path = tmp("roundtrip");
        let st = SharedTensor::create(&path, &[2, 3], DType::F32).unwrap();
        let src = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        st.copy_from(&src);
        let view = st.tensor();
        assert_eq!(view.to_vec::<f32>(), src.to_vec::<f32>());
        // Re-open by path like another process would.
        let st2 = SharedTensor::open(&path).unwrap();
        assert_eq!(st2.shape, vec![2, 3]);
        assert_eq!(st2.tensor().to_vec::<f32>(), src.to_vec::<f32>());
        st.unlink();
    }

    #[test]
    fn shared_view_is_zero_copy() {
        let path = tmp("zerocopy");
        let st = SharedTensor::create(&path, &[4], DType::F32).unwrap();
        let a = st.tensor();
        let b = st.tensor();
        a.fill_(7.0);
        assert_eq!(b.to_vec::<f32>(), vec![7.0; 4]);
        st.unlink();
    }

    #[test]
    fn fork_workers_write_disjoint_ranks() {
        let path = tmp("ranks");
        let st = SharedTensor::create(&path, &[4], DType::F32).unwrap();
        let p = path.clone();
        fork_workers(4, move |rank| {
            let st = SharedTensor::open(&p).unwrap();
            let view = st.tensor();
            // Write rank+1 at slot `rank` via narrow view.
            let slot = view.narrow(0, rank, 1);
            crate::ops::copy_into_view_public(&slot, &Tensor::from_slice(&[(rank + 1) as f32]));
        })
        .unwrap();
        assert_eq!(st.tensor().to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0]);
        st.unlink();
    }

    #[test]
    fn fork_worker_failure_is_reported() {
        let r = fork_workers(2, |rank| {
            if rank == 1 {
                panic!("worker bug");
            }
        });
        // The failure must be typed — rank, pid, and exit mode as data —
        // and its Display must still name the failed rank and how it died
        // (a panicking child _exits with 101), not just count failures.
        let err = r.unwrap_err();
        match &err {
            TorskError::Workers { total, failed } => {
                assert_eq!(*total, 2);
                assert_eq!(failed.len(), 1);
                assert_eq!(failed[0].rank, 1);
                assert!(failed[0].pid > 0);
                assert_eq!(failed[0].exit, RankExit::Exited(101));
            }
            other => panic!("expected TorskError::Workers, got: {other}"),
        }
        let s = err.to_string();
        assert!(s.contains("1 of 2 worker(s) failed"), "{s}");
        assert!(s.contains("rank 1"), "{s}");
        assert!(s.contains("exited with status 101"), "{s}");
    }

    #[test]
    fn allreduce_mean_across_processes() {
        let path = tmp("allreduce");
        let scratch = SharedTensor::create(&path, &[3], DType::F32).unwrap();
        let out_path = tmp("allreduce_out");
        let out = SharedTensor::create(&out_path, &[4, 3], DType::F32).unwrap();
        let (p1, p2) = (path.clone(), out_path.clone());
        fork_workers(4, move |rank| {
            let scratch = SharedTensor::open(&p1).unwrap();
            let outs = SharedTensor::open(&p2).unwrap();
            let barrier = ShmBarrier::on(&scratch, 4);
            let local = Tensor::full(&[3], (rank + 1) as f32);
            let mean = allreduce_mean(&local, &scratch, &barrier, 4);
            let row = outs.tensor().narrow(0, rank, 1).reshape(&[3]);
            crate::ops::copy_into_view_public(&row, &mean);
        })
        .unwrap();
        // mean of 1,2,3,4 = 2.5 for every rank.
        assert_eq!(out.tensor().to_vec::<f32>(), vec![2.5; 12]);
        scratch.unlink();
        out.unlink();
    }

    #[test]
    fn pipe_roundtrip_preserves_data() {
        let t = Tensor::from_vec((0..1000).map(|i| i as f32).collect(), &[1000]);
        let back = pipe_roundtrip(&t).unwrap();
        assert_eq!(back.to_vec::<f32>(), t.to_vec::<f32>());
    }

    #[test]
    fn shm_lock_mutual_exclusion_threads() {
        let path = tmp("lock");
        let st = Arc::new(SharedTensor::create(&path, &[1], DType::F32).unwrap());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let st = st.clone();
                s.spawn(move || {
                    for _ in 0..250 {
                        let _g = st.lock();
                        let t = st.tensor();
                        let v = t.to_vec::<f32>()[0];
                        t.fill_(v + 1.0);
                    }
                });
            }
        });
        assert_eq!(st.tensor().to_vec::<f32>(), vec![1000.0]);
        st.unlink();
    }
}
