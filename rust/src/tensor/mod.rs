//! The `Tensor`: a strided, reference-counted, device-placed array with
//! autograd metadata — torsk's equivalent of `torch.Tensor` backed by the
//! libtorch-style core (§5.1).
//!
//! Cloning a `Tensor` is a cheap `Arc` bump; views (reshape, transpose,
//! narrow, expand) share storage. Interop is zero-copy where possible
//! (§4.2): `from_vec` adopts host data, `to_vec` copies out.

pub mod dtype;
pub mod shape;
pub mod storage;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::alloc::StreamId;
use crate::autograd::{self, AutogradMeta};
use crate::device::{self, Device};
use crate::{rng, torsk_assert, torsk_bail};

pub use dtype::{DType, Element, FloatElement};
use storage::{SendPtr, Storage};

static NEXT_TENSOR_ID: AtomicU64 = AtomicU64::new(1);

pub(crate) struct TensorImpl {
    pub(crate) storage: Storage,
    /// Offset into storage, in elements.
    pub(crate) offset: usize,
    pub(crate) shape: Vec<usize>,
    pub(crate) strides: Vec<usize>,
    pub(crate) dtype: DType,
    pub(crate) autograd: Mutex<AutogradMeta>,
    pub(crate) id: u64,
}

/// A multi-dimensional array handle. Cheap to clone; shares storage.
#[derive(Clone)]
pub struct Tensor {
    pub(crate) inner: Arc<TensorImpl>,
}

fn stream_for(device: Device) -> StreamId {
    match device {
        Device::Cpu => StreamId::HOST,
        Device::Sim => device::current_stream_id(),
    }
}

impl Tensor {
    // ------------------------------------------------------------------
    // Construction
    // ------------------------------------------------------------------

    pub(crate) fn from_parts(
        storage: Storage,
        offset: usize,
        shape: Vec<usize>,
        strides: Vec<usize>,
        dtype: DType,
    ) -> Tensor {
        Tensor {
            inner: Arc::new(TensorImpl {
                storage,
                offset,
                shape,
                strides,
                dtype,
                autograd: Mutex::new(AutogradMeta::default()),
                id: NEXT_TENSOR_ID.fetch_add(1, Ordering::Relaxed),
            }),
        }
    }

    /// Wrap an externally-owned memory block (shared memory, §5.4) as a
    /// tensor. The allocator keeps the real owner alive and ignores the
    /// block on drop.
    pub fn from_external_block(
        block: crate::alloc::Block,
        nbytes: usize,
        shape: Vec<usize>,
        dtype: DType,
        allocator: crate::alloc::ArcAllocator,
    ) -> Tensor {
        let strides = shape::contiguous_strides(&shape);
        let storage = Storage::from_block(block, nbytes, Device::Cpu, allocator);
        Tensor::from_parts(storage, 0, shape, strides, dtype)
    }

    /// Uninitialized tensor on `device` (contents unspecified).
    pub fn empty(shape: &[usize], dtype: DType, device: Device) -> Tensor {
        let n = shape::numel(shape);
        let storage = Storage::new(n * dtype.size(), device, stream_for(device));
        Tensor::from_parts(storage, 0, shape.to_vec(), shape::contiguous_strides(shape), dtype)
    }

    /// Adopt a host vector (zero further copies).
    pub fn from_vec<T: Element>(data: Vec<T>, shape: &[usize]) -> Tensor {
        torsk_assert!(
            data.len() == shape::numel(shape),
            "from_vec: {} elements for shape {:?}",
            data.len(),
            shape
        );
        let storage = Storage::from_slice(&data);
        let t =
            Tensor::from_parts(storage, 0, shape.to_vec(), shape::contiguous_strides(shape), T::DTYPE);
        // Honor the thread's default device (torch.set_default_device).
        let dev = device::default_device();
        if dev != Device::Cpu {
            t.to_device(dev)
        } else {
            t
        }
    }

    /// 1-D helper.
    pub fn from_slice<T: Element>(data: &[T]) -> Tensor {
        Tensor::from_vec(data.to_vec(), &[data.len()])
    }

    /// Scalar (0-dim) tensor.
    pub fn scalar(v: f32) -> Tensor {
        Tensor::from_vec(vec![v], &[])
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::full(shape, 0.0)
    }

    /// Zeros with explicit dtype/device.
    pub fn zeros_on(shape: &[usize], dtype: DType, device: Device) -> Tensor {
        let t = Tensor::empty(shape, dtype, device);
        t.fill_bytes_zero();
        t
    }

    /// All-ones tensor.
    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor::full(shape, 1.0)
    }

    /// Constant-filled f32 host tensor.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor::from_vec(vec![v; shape::numel(shape)], shape)
    }

    /// Same shape/dtype/device as `self`, filled with `v`.
    pub fn full_like(&self, v: f32) -> Tensor {
        let t = Tensor::empty(self.shape(), self.dtype(), self.device());
        t.fill_(v);
        t
    }

    /// Same shape/dtype/device as `self`, all ones.
    pub fn ones_like(&self) -> Tensor {
        self.full_like(1.0)
    }

    /// Same shape/dtype/device as `self`, all zeros.
    pub fn zeros_like(&self) -> Tensor {
        let t = Tensor::empty(self.shape(), self.dtype(), self.device());
        t.fill_bytes_zero();
        t
    }

    /// Standard-normal samples (global RNG; see [`crate::rng::manual_seed`]).
    pub fn randn(shape: &[usize]) -> Tensor {
        let mut data = vec![0.0f32; shape::numel(shape)];
        rng::with_rng(|r| r.fill_normal(&mut data, 0.0, 1.0));
        Tensor::from_vec(data, shape)
    }

    /// Uniform [0,1) samples.
    pub fn rand(shape: &[usize]) -> Tensor {
        let mut data = vec![0.0f32; shape::numel(shape)];
        rng::with_rng(|r| r.fill_uniform(&mut data, 0.0, 1.0));
        Tensor::from_vec(data, shape)
    }

    /// Random integers in [0, hi) as i64.
    pub fn randint(hi: i64, shape: &[usize]) -> Tensor {
        torsk_assert!(hi > 0, "randint: hi must be positive");
        let data: Vec<i64> =
            rng::with_rng(|r| (0..shape::numel(shape)).map(|_| r.below(hi as u64) as i64).collect());
        Tensor::from_vec(data, shape)
    }

    /// `[0, 1, ..., n-1]` as f32.
    pub fn arange(n: usize) -> Tensor {
        Tensor::from_vec((0..n).map(|i| i as f32).collect(), &[n])
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Tensor {
        let mut data = vec![0.0f32; n * n];
        for i in 0..n {
            data[i * n + i] = 1.0;
        }
        Tensor::from_vec(data, &[n, n])
    }

    fn fill_bytes_zero(&self) {
        let ptr = SendPtr::new(unsafe { (self.inner.storage.ptr()).add(self.inner.offset * self.inner.dtype.size()) });
        let nbytes = self.numel() * self.inner.dtype.size();
        // SAFETY: pointer/length pairs come from shape-checked live tensors
        // captured at enqueue time. On CPU this closure runs inline while the
        // caller's handles are alive; on a stream, the one-pool-per-stream
        // FIFO allocator guarantees freed storage is only reused by kernels
        // enqueued later on the same stream, so the bytes stay valid (and
        // writes exclusive) until this kernel completes.
        device::dispatch(self.device(), "zero_fill", move || unsafe {
            std::ptr::write_bytes(ptr.ptr(), 0, nbytes);
        });
    }

    // ------------------------------------------------------------------
    // Metadata
    // ------------------------------------------------------------------

    /// Shape of the tensor.
    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.inner.shape
    }

    /// Strides, in elements.
    #[inline]
    pub fn strides(&self) -> &[usize] {
        &self.inner.strides
    }

    /// Number of dimensions.
    #[inline]
    pub fn ndim(&self) -> usize {
        self.inner.shape.len()
    }

    /// Total element count.
    #[inline]
    pub fn numel(&self) -> usize {
        shape::numel(&self.inner.shape)
    }

    /// Size along dimension `d`.
    #[inline]
    pub fn size(&self, d: usize) -> usize {
        self.inner.shape[d]
    }

    #[inline]
    pub fn dtype(&self) -> DType {
        self.inner.dtype
    }

    #[inline]
    pub fn device(&self) -> Device {
        self.inner.storage.device()
    }

    /// Unique tensor id (diagnostics).
    #[inline]
    pub fn id(&self) -> u64 {
        self.inner.id
    }

    /// Is the memory layout dense row-major?
    pub fn is_contiguous(&self) -> bool {
        shape::is_contiguous(&self.inner.shape, &self.inner.strides)
    }

    /// Underlying storage handle.
    pub fn storage(&self) -> &Storage {
        &self.inner.storage
    }

    /// Element offset into storage.
    pub fn storage_offset(&self) -> usize {
        self.inner.offset
    }

    /// Do two tensors share storage memory?
    pub fn shares_storage(&self, other: &Tensor) -> bool {
        self.inner.storage.same_memory(&other.inner.storage)
    }

    // ------------------------------------------------------------------
    // Autograd metadata (mechanics live in crate::autograd)
    // ------------------------------------------------------------------

    /// Builder-style: mark this tensor as requiring gradients.
    pub fn requires_grad(self, on: bool) -> Tensor {
        self.set_requires_grad(on);
        self
    }

    /// Mark as requiring gradients (leaf tensor).
    pub fn set_requires_grad(&self, on: bool) {
        let mut meta = self.inner.autograd.lock().unwrap();
        torsk_assert!(
            !on || meta.grad_fn.is_none(),
            "requires_grad can only be set on leaf tensors"
        );
        meta.requires_grad = on;
    }

    /// Whether gradients flow through this tensor.
    pub fn requires_grad_flag(&self) -> bool {
        let meta = self.inner.autograd.lock().unwrap();
        meta.requires_grad || meta.grad_fn.is_some()
    }

    /// Accumulated gradient (leaves only, after `backward`).
    pub fn grad(&self) -> Option<Tensor> {
        self.inner.autograd.lock().unwrap().grad.clone()
    }

    /// Overwrite the gradient (used by optimizers' `zero_grad`).
    pub fn set_grad(&self, g: Option<Tensor>) {
        self.inner.autograd.lock().unwrap().grad = g;
    }

    /// The grad_fn node that produced this tensor, if any.
    pub fn grad_fn(&self) -> Option<Arc<autograd::Node>> {
        self.inner.autograd.lock().unwrap().grad_fn.clone()
    }

    pub(crate) fn set_grad_fn(&self, node: Arc<autograd::Node>) {
        self.inner.autograd.lock().unwrap().grad_fn = Some(node);
    }

    /// A view sharing storage but detached from the autograd graph
    /// (`tensor.detach()` in the paper's GAN listing).
    pub fn detach(&self) -> Tensor {
        Tensor::from_parts(
            self.inner.storage.clone(),
            self.inner.offset,
            self.inner.shape.clone(),
            self.inner.strides.clone(),
            self.inner.dtype,
        )
    }

    /// Run reverse-mode AD from this scalar (see [`autograd::backward`]).
    pub fn backward(&self) {
        autograd::backward(self, None);
    }

    /// Backward with an explicit seed gradient.
    pub fn backward_with(&self, grad: Tensor) {
        autograd::backward(self, Some(grad));
    }

    /// Storage mutation version (§4.3 versioning).
    pub fn version(&self) -> u64 {
        self.inner.storage.version()
    }

    /// Bump the version after an in-place mutation.
    pub(crate) fn bump_version(&self) {
        self.inner.storage.bump_version();
    }

    // ------------------------------------------------------------------
    // Raw access for kernels
    // ------------------------------------------------------------------

    /// Base pointer at this tensor's storage offset.
    pub(crate) fn data_ptr(&self) -> SendPtr {
        // SAFETY: offset is within the storage by construction.
        SendPtr::new(unsafe { self.inner.storage.ptr().add(self.inner.offset * self.inner.dtype.size()) })
    }

    /// Host-side typed slice. Requires contiguity; syncs the device first
    /// if the tensor lives on the simulated accelerator.
    pub fn with_data<T: Element, R>(&self, f: impl FnOnce(&[T]) -> R) -> R {
        torsk_assert!(self.dtype() == T::DTYPE, "dtype mismatch: {} vs {}", self.dtype(), T::DTYPE);
        torsk_assert!(self.is_contiguous(), "with_data requires contiguous tensor");
        if self.device().is_async() {
            device::synchronize();
        }
        // SAFETY: contiguity was asserted, so offset..offset+numel is in
        // bounds; the device sync above ordered any pending writes.
        let s: &[T] = unsafe { self.inner.storage.slice(self.inner.offset, self.numel()) };
        f(s)
    }

    /// Copy the (contiguous view of the) tensor out to a host `Vec`.
    pub fn to_vec<T: Element>(&self) -> Vec<T> {
        let c = self.contiguous();
        c.with_data::<T, Vec<T>>(|s| s.to_vec())
    }

    /// Extract the single element of a scalar tensor as f32 (converting
    /// from f64/i64 scalars).
    pub fn item(&self) -> f32 {
        torsk_assert!(self.numel() == 1, "item() on tensor with {} elements", self.numel());
        match self.dtype() {
            DType::F32 => self.to_vec::<f32>()[0],
            DType::F64 => self.to_vec::<f64>()[0] as f32,
            DType::I64 => self.to_vec::<i64>()[0] as f32,
        }
    }

    /// Extract a single i64 element.
    pub fn item_i64(&self) -> i64 {
        torsk_assert!(self.numel() == 1, "item_i64() on tensor with {} elements", self.numel());
        self.to_vec::<i64>()[0]
    }

    // ------------------------------------------------------------------
    // Views (share storage, no data movement)
    // ------------------------------------------------------------------

    fn view_of(&self, offset: usize, shape: Vec<usize>, strides: Vec<usize>) -> Tensor {
        let t = Tensor::from_parts(self.inner.storage.clone(), offset, shape, strides, self.inner.dtype);
        // Views participate in the graph through the ops layer; raw views
        // here propagate requires_grad for leaves so mistakes surface.
        t
    }

    /// Reshape. Zero-copy when contiguous, copying otherwise. `-1`-style
    /// inference: pass `usize::MAX` for at most one dimension.
    pub fn reshape(&self, new_shape: &[usize]) -> Tensor {
        let mut dims: Vec<usize> = new_shape.to_vec();
        let known: usize = dims.iter().filter(|&&d| d != usize::MAX).product();
        let inferred = dims.iter().filter(|&&d| d == usize::MAX).count();
        torsk_assert!(inferred <= 1, "reshape: at most one inferred dimension");
        if inferred == 1 {
            torsk_assert!(known > 0 && self.numel() % known == 0, "reshape: cannot infer dim");
            for d in dims.iter_mut() {
                if *d == usize::MAX {
                    *d = self.numel() / known;
                }
            }
        }
        torsk_assert!(
            shape::numel(&dims) == self.numel(),
            "reshape: {:?} -> {:?} changes element count",
            self.shape(),
            dims
        );
        let base = if self.is_contiguous() { self.clone() } else { self.contiguous() };
        let strides = shape::contiguous_strides(&dims);
        let out = base.view_of(base.inner.offset, dims, strides);
        crate::ops::register_view_grad(self, &out);
        out
    }

    /// Swap two dimensions (zero-copy).
    pub fn transpose(&self, d0: usize, d1: usize) -> Tensor {
        let mut sh = self.inner.shape.clone();
        let mut st = self.inner.strides.clone();
        sh.swap(d0, d1);
        st.swap(d0, d1);
        let out = self.view_of(self.inner.offset, sh, st);
        crate::ops::register_transpose_grad(self, &out, d0, d1);
        out
    }

    /// Matrix transpose of a 2-D tensor.
    pub fn t(&self) -> Tensor {
        torsk_assert!(self.ndim() == 2, "t() requires 2-D, got {:?}", self.shape());
        self.transpose(0, 1)
    }

    /// Permute dimensions (zero-copy).
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        torsk_assert!(perm.len() == self.ndim(), "permute: rank mismatch");
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            torsk_assert!(p < perm.len() && !seen[p], "permute: invalid permutation {:?}", perm);
            seen[p] = true;
        }
        let sh: Vec<usize> = perm.iter().map(|&p| self.inner.shape[p]).collect();
        let st: Vec<usize> = perm.iter().map(|&p| self.inner.strides[p]).collect();
        let out = self.view_of(self.inner.offset, sh, st);
        crate::ops::register_permute_grad(self, &out, perm);
        out
    }

    /// Slice dimension `dim` to `[start, start+len)` (zero-copy).
    pub fn narrow(&self, dim: usize, start: usize, len: usize) -> Tensor {
        torsk_assert!(dim < self.ndim(), "narrow: dim {} out of range", dim);
        torsk_assert!(
            start + len <= self.inner.shape[dim],
            "narrow: [{start}, {}) out of bounds for dim of size {}",
            start + len,
            self.inner.shape[dim]
        );
        let mut sh = self.inner.shape.clone();
        sh[dim] = len;
        let offset = self.inner.offset + start * self.inner.strides[dim];
        let out = self.view_of(offset, sh, self.inner.strides.clone());
        crate::ops::register_narrow_grad(self, &out, dim, start);
        out
    }

    /// Index dimension `dim` at `idx`, removing it (zero-copy).
    pub fn select(&self, dim: usize, idx: usize) -> Tensor {
        let narrowed = self.narrow(dim, idx, 1);
        narrowed.squeeze(dim)
    }

    /// Remove a size-1 dimension.
    pub fn squeeze(&self, dim: usize) -> Tensor {
        torsk_assert!(self.inner.shape[dim] == 1, "squeeze: dim {dim} has size != 1");
        let mut sh = self.inner.shape.clone();
        let mut st = self.inner.strides.clone();
        sh.remove(dim);
        st.remove(dim);
        let out = self.view_of(self.inner.offset, sh, st);
        crate::ops::register_view_grad(self, &out);
        out
    }

    /// Insert a size-1 dimension.
    pub fn unsqueeze(&self, dim: usize) -> Tensor {
        torsk_assert!(dim <= self.ndim(), "unsqueeze: dim {dim} out of range");
        let mut sh = self.inner.shape.clone();
        let mut st = self.inner.strides.clone();
        let stride = if dim < st.len() { st[dim] * sh.get(dim).copied().unwrap_or(1) } else { 1 };
        sh.insert(dim, 1);
        st.insert(dim, stride.max(1));
        let out = self.view_of(self.inner.offset, sh, st);
        crate::ops::register_view_grad(self, &out);
        out
    }

    /// Broadcast view to `target` shape (stride-0 on expanded axes).
    pub fn expand(&self, target: &[usize]) -> Tensor {
        let st = shape::broadcast_strides(&self.inner.shape, &self.inner.strides, target);
        let out = self.view_of(self.inner.offset, target.to_vec(), st);
        crate::ops::register_expand_grad(self, &out);
        out
    }

    /// Dense row-major copy (no-op clone of the handle when already
    /// contiguous).
    pub fn contiguous(&self) -> Tensor {
        if self.is_contiguous() {
            return self.clone();
        }
        let out = Tensor::empty(&self.inner.shape, self.inner.dtype, self.device());
        let src = self.data_ptr();
        let dst = out.data_ptr();
        let sh = self.inner.shape.clone();
        let st = self.inner.strides.clone();
        let n = self.numel();
        let dtype = self.inner.dtype;
        // SAFETY: in all three arms `dst` is the fresh n-element output,
        // `src` offsets walk the validated strided extent of `self`; both
        // storages stay alive per the stream FIFO discipline.
        device::dispatch(self.device(), "contiguous", move || match dtype {
            DType::F32 => unsafe {
                let d = dst.as_mut_slice::<f32>(0, n);
                for (i, off) in shape::StridedIter::new(&sh, &st).enumerate() {
                    d[i] = *src.as_f32().add(off);
                }
            },
            // SAFETY: see the F32 arm.
            DType::F64 => unsafe {
                let d = dst.as_mut_slice::<f64>(0, n);
                for (i, off) in shape::StridedIter::new(&sh, &st).enumerate() {
                    d[i] = *(src.ptr() as *const f64).add(off);
                }
            },
            // SAFETY: see the F32 arm.
            DType::I64 => unsafe {
                let d = dst.as_mut_slice::<i64>(0, n);
                for (i, off) in shape::StridedIter::new(&sh, &st).enumerate() {
                    d[i] = *(src.ptr() as *const i64).add(off);
                }
            },
        });
        crate::ops::register_view_grad(self, &out);
        out
    }

    // ------------------------------------------------------------------
    // Device movement
    // ------------------------------------------------------------------

    /// Copy to `device` (returns self's clone when already there).
    pub fn to_device(&self, device: Device) -> Tensor {
        if self.device() == device {
            return self.clone();
        }
        let src = self.contiguous();
        if src.device().is_async() {
            // d2h: wait for producers before reading.
            crate::device::synchronize();
        }
        let out = Tensor::empty(src.shape(), src.dtype(), device);
        let nbytes = src.numel() * src.dtype().size();
        let s = src.data_ptr();
        let d = out.data_ptr();
        // h2d / d2h transfer: queued on the stream like cudaMemcpyAsync so
        // it orders correctly with subsequent kernels on the same stream.
        // The closure keeps the *host* source alive until the copy runs:
        // host memory is not protected by the per-stream pool-reuse
        // argument (§5.3 applies to device streams only), so a
        // pointer-only capture could read a recycled host buffer. This is
        // the cross-device hazard the paper says utilities must handle by
        // "carefully inserting additional synchronization".
        let keep_src = src.detach();
        // SAFETY: pointer/length pairs come from shape-checked live tensors
        // captured at enqueue time. On CPU this closure runs inline while the
        // caller's handles are alive; on a stream, the one-pool-per-stream
        // FIFO allocator guarantees freed storage is only reused by kernels
        // enqueued later on the same stream, so the bytes stay valid (and
        // writes exclusive) until this kernel completes.
        device::dispatch(device, "memcpy", move || unsafe {
            std::ptr::copy_nonoverlapping(s.ptr(), d.ptr(), nbytes);
            drop(keep_src);
        });
        crate::ops::register_view_grad(self, &out);
        out
    }

    /// Shorthand: move to the simulated accelerator.
    pub fn to_sim(&self) -> Tensor {
        self.to_device(Device::Sim)
    }

    /// Shorthand: move to host.
    pub fn to_cpu(&self) -> Tensor {
        self.to_device(Device::Cpu)
    }
}

impl std::fmt::Debug for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Tensor(shape={:?}, dtype={}, device={}{}{})",
            self.shape(),
            self.dtype(),
            self.device(),
            if self.requires_grad_flag() { ", requires_grad" } else { "" },
            if self.grad_fn().is_some() { ", grad_fn" } else { "" },
        )
    }
}

/// Host copy of any-dtype tensor data, widened to f64 (test/diagnostic
/// helper).
pub fn to_f64_vec(t: &Tensor) -> Vec<f64> {
    match t.dtype() {
        DType::F32 => t.to_vec::<f32>().into_iter().map(|x| x as f64).collect(),
        DType::F64 => t.to_vec::<f64>(),
        DType::I64 => t.to_vec::<i64>().into_iter().map(|x| x as f64).collect(),
    }
}

/// Panic unless two tensors are elementwise close (test helper, mirrors
/// `torch.testing.assert_close`). Works across dtypes by comparing in f64.
pub fn assert_close(a: &Tensor, b: &Tensor, atol: f32, rtol: f32) {
    torsk_assert!(a.shape() == b.shape(), "shape mismatch {:?} vs {:?}", a.shape(), b.shape());
    let av = to_f64_vec(a);
    let bv = to_f64_vec(b);
    for (i, (&x, &y)) in av.iter().zip(bv.iter()).enumerate() {
        let tol = atol as f64 + rtol as f64 * y.abs();
        if (x - y).abs() > tol && !(x.is_nan() && y.is_nan()) {
            torsk_bail!("tensors differ at flat index {i}: {x} vs {y} (tol {tol})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creation_and_metadata() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.device(), Device::Cpu);
        assert!(t.is_contiguous());
        assert_eq!(t.to_vec::<f32>(), vec![0.0; 6]);
    }

    #[test]
    fn from_vec_roundtrip() {
        let t = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "from_vec")]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(vec![1.0f32; 3], &[2, 2]);
    }

    #[test]
    fn clone_shares_storage() {
        let t = Tensor::ones(&[4]);
        // Handle clones share the same TensorImpl (like Python references).
        let u = t.clone();
        assert!(t.shares_storage(&u));
        assert_eq!(t.storage().ref_count(), 1);
        // Views create a new TensorImpl over the same storage — the §5.5
        // refcount observably increases.
        let v = t.reshape(&[2, 2]);
        assert!(t.shares_storage(&v));
        assert_eq!(t.storage().ref_count(), 2);
        drop(v);
        assert_eq!(t.storage().ref_count(), 1);
    }

    #[test]
    fn transpose_is_zero_copy_view() {
        let t = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let tt = t.t();
        assert_eq!(tt.shape(), &[3, 2]);
        assert!(t.shares_storage(&tt));
        assert!(!tt.is_contiguous());
        assert_eq!(tt.to_vec::<f32>(), vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn reshape_infers_dimension() {
        let t = Tensor::arange(12);
        let r = t.reshape(&[3, usize::MAX]);
        assert_eq!(r.shape(), &[3, 4]);
        assert!(t.shares_storage(&r));
    }

    #[test]
    fn narrow_and_select() {
        let t = Tensor::from_vec((0..12).map(|i| i as f32).collect(), &[3, 4]);
        let row = t.select(0, 1);
        assert_eq!(row.shape(), &[4]);
        assert_eq!(row.to_vec::<f32>(), vec![4.0, 5.0, 6.0, 7.0]);
        let cols = t.narrow(1, 1, 2);
        assert_eq!(cols.shape(), &[3, 2]);
        assert_eq!(cols.to_vec::<f32>(), vec![1.0, 2.0, 5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn expand_broadcasts_with_stride_zero() {
        let t = Tensor::from_vec(vec![1.0f32, 2.0, 3.0], &[3]);
        let e = t.expand(&[2, 3]);
        assert_eq!(e.shape(), &[2, 3]);
        assert_eq!(e.to_vec::<f32>(), vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        assert!(t.shares_storage(&e));
    }

    #[test]
    fn contiguous_copies_transposed_layout() {
        let t = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]);
        let tt = t.t().contiguous();
        assert!(tt.is_contiguous());
        assert!(!t.shares_storage(&tt));
        assert_eq!(tt.to_vec::<f32>(), vec![1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn squeeze_unsqueeze_roundtrip() {
        let t = Tensor::ones(&[2, 3]);
        let u = t.unsqueeze(1);
        assert_eq!(u.shape(), &[2, 1, 3]);
        let s = u.squeeze(1);
        assert_eq!(s.shape(), &[2, 3]);
    }

    #[test]
    fn item_scalar() {
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }

    #[test]
    #[should_panic(expected = "item()")]
    fn item_on_nonscalar_panics() {
        Tensor::ones(&[2]).item();
    }

    #[test]
    fn eye_and_arange() {
        let e = Tensor::eye(2);
        assert_eq!(e.to_vec::<f32>(), vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(Tensor::arange(3).to_vec::<f32>(), vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn randn_respects_manual_seed() {
        rng::manual_seed(99);
        let a = Tensor::randn(&[8]).to_vec::<f32>();
        rng::manual_seed(99);
        let b = Tensor::randn(&[8]).to_vec::<f32>();
        assert_eq!(a, b);
    }

    #[test]
    fn to_sim_and_back_preserves_data() {
        let t = Tensor::from_vec(vec![1.0f32, -2.0, 3.5], &[3]);
        let d = t.to_sim();
        assert_eq!(d.device(), Device::Sim);
        let h = d.to_cpu();
        assert_eq!(h.to_vec::<f32>(), vec![1.0, -2.0, 3.5]);
    }

    #[test]
    fn detach_shares_memory_without_graph() {
        let t = Tensor::ones(&[2]).requires_grad(true);
        let d = t.detach();
        assert!(t.shares_storage(&d));
        assert!(!d.requires_grad_flag());
    }

    #[test]
    fn randint_in_range() {
        let t = Tensor::randint(5, &[100]);
        for v in t.to_vec::<i64>() {
            assert!((0..5).contains(&v));
        }
    }

    #[test]
    fn assert_close_passes_and_fails() {
        let a = Tensor::from_slice(&[1.0f32, 2.0]);
        let b = Tensor::from_slice(&[1.0f32, 2.0 + 1e-7]);
        assert_close(&a, &b, 1e-5, 1e-5);
        let c = Tensor::from_slice(&[1.0f32, 3.0]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| assert_close(&a, &c, 1e-5, 1e-5)));
        assert!(r.is_err());
    }
}
