//! Tensor storage: reference-counted raw memory + the mutation version
//! counter.
//!
//! Two load-bearing paper mechanisms live here:
//!
//! - **§5.5 reference counting.** `Storage` is an `Arc` around a block from
//!   an [`Allocator`]; the moment the last reference drops, `Drop` returns
//!   the block — memory is released *immediately* when tensors become
//!   unneeded, not at some future GC pause. Rust is exactly the kind of
//!   language the paper calls out as compatible ("allow for user-defined
//!   behavior for assignment, copies, and moves (e.g. C++, Rust)").
//!
//! - **§4.3 versioning.** Every storage carries a monotonically increasing
//!   version, bumped by each in-place mutation. The autograd system
//!   snapshots the version when saving a tensor for backward and refuses
//!   to use it if the version moved — the paper's deliberate
//!   "user error instead of copy-on-write" tradeoff.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::alloc::{ArcAllocator, Block, StreamId};
use crate::ctx;
use crate::device::Device;
use crate::tensor::dtype::Element;

// ---------------------------------------------------------------------
// Output-buffer donation (the dispatcher's output-reuse hook)
// ---------------------------------------------------------------------

thread_local! {
    /// A storage donated by `dispatch::call_owned`: the next
    /// [`Storage::new`] on this thread requesting exactly this
    /// (nbytes, device, stream) takes it instead of allocating. Armed only
    /// for the duration of one dispatched op; see the "Threading and
    /// memory model" section of `crate::dispatch` for the stealing rules.
    static DONATED: RefCell<Option<Storage>> = RefCell::new(None);
}

/// Arm the donation slot with a storage proven dead by ownership
/// (`dispatch::call_owned` moved the last handle in). Replaces any
/// previous, unconsumed donation.
pub(crate) fn arm_donation(s: Storage) {
    DONATED.with(|d| *d.borrow_mut() = Some(s));
}

/// Clear the donation slot. Returns the storage if the op did *not*
/// consume it (`None` therefore means the output stole the buffer).
pub(crate) fn disarm_donation() -> Option<Storage> {
    DONATED.with(|d| d.borrow_mut().take())
}

fn take_donated(nbytes: usize, device: Device, stream: StreamId) -> Option<Storage> {
    DONATED.with(|d| {
        let mut slot = d.borrow_mut();
        match &*slot {
            Some(s) if s.nbytes() == nbytes && s.device() == device && s.stream() == stream => {
                // Sanitizer: the buffer the output is about to steal must
                // be dead (slot clone + moved-in input handle only).
                #[cfg(feature = "debug-checks")]
                crate::debug_checks::verify_donation_dead(s);
                slot.take()
            }
            _ => None,
        }
    })
}

struct StorageImpl {
    block: Block,
    nbytes: usize,
    device: Device,
    allocator: ArcAllocator,
    version: AtomicU64,
}

impl Drop for StorageImpl {
    fn drop(&mut self) {
        // Immediate reclamation (§5.5): hand the block straight back.
        let block = Block {
            ptr: self.block.ptr,
            size: self.block.size,
            requested: self.block.requested,
            stream: self.block.stream,
            root: self.block.root,
        };
        self.allocator.deallocate(block);
    }
}

// SAFETY: raw memory region; cross-thread access is coordinated by the
// stream discipline (device kernels) or exclusive ownership (host).
unsafe impl Send for StorageImpl {}
unsafe impl Sync for StorageImpl {}

/// Reference-counted tensor storage.
#[derive(Clone)]
pub struct Storage {
    inner: Arc<StorageImpl>,
}

impl Storage {
    /// Allocate `nbytes` on `device` from that device's current allocator,
    /// bound to `stream`'s pool. If the dispatcher armed a donation of
    /// exactly this size/device/stream, the donated storage is returned
    /// instead — zero allocator traffic (the output "steals" a dead
    /// input's buffer).
    pub fn new(nbytes: usize, device: Device, stream: StreamId) -> Storage {
        if let Some(s) = take_donated(nbytes, device, stream) {
            return s;
        }
        let allocator = ctx::allocator_for(device);
        let block = allocator.allocate(nbytes, stream);
        Storage {
            inner: Arc::new(StorageImpl {
                block,
                nbytes,
                device,
                allocator,
                version: AtomicU64::new(0),
            }),
        }
    }

    /// Wrap an externally-owned block (e.g. shared memory). `allocator`
    /// receives the block back on drop — pass a no-op allocator that keeps
    /// the real owner alive.
    pub fn from_block(block: Block, nbytes: usize, device: Device, allocator: ArcAllocator) -> Storage {
        Storage {
            inner: Arc::new(StorageImpl {
                block,
                nbytes,
                device,
                allocator,
                version: AtomicU64::new(0),
            }),
        }
    }

    /// Host storage initialized from a slice.
    pub fn from_slice<T: Element>(data: &[T]) -> Storage {
        let nbytes = std::mem::size_of_val(data);
        let s = Storage::new(nbytes, Device::Cpu, StreamId::HOST);
        // SAFETY: freshly allocated, exclusively owned, sized for `data`.
        unsafe {
            std::ptr::copy_nonoverlapping(data.as_ptr() as *const u8, s.ptr(), nbytes);
        }
        s
    }

    /// Raw base pointer.
    #[inline]
    pub fn ptr(&self) -> *mut u8 {
        self.inner.block.ptr.as_ptr()
    }

    /// Capacity in bytes actually requested (not the rounded block size).
    #[inline]
    pub fn nbytes(&self) -> usize {
        self.inner.nbytes
    }

    #[inline]
    pub fn device(&self) -> Device {
        self.inner.device
    }

    /// Stream whose allocator pool owns the block.
    #[inline]
    pub fn stream(&self) -> StreamId {
        self.inner.block.stream
    }

    /// Number of `Storage` handles sharing this memory (the §5.5 refcount,
    /// observable for tests and the refcount-vs-GC bench).
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.inner)
    }

    /// Identity: do two storages share memory?
    pub fn same_memory(&self, other: &Storage) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Current mutation version (§4.3).
    #[inline]
    pub fn version(&self) -> u64 {
        self.inner.version.load(Ordering::Acquire)
    }

    /// Bump the version — called by every in-place mutation.
    #[inline]
    pub fn bump_version(&self) {
        self.inner.version.fetch_add(1, Ordering::AcqRel);
    }

    /// Typed view of `len` elements starting `offset` elements in.
    ///
    /// # Safety
    /// Caller must ensure (a) the range is in bounds, (b) no concurrent
    /// mutation — for device storage that means required stream syncs have
    /// happened.
    #[inline]
    pub unsafe fn slice<T: Element>(&self, offset: usize, len: usize) -> &[T] {
        debug_assert!((offset + len) * std::mem::size_of::<T>() <= self.inner.block.size);
        // SAFETY: in-bounds and race-free per this fn's contract.
        unsafe { std::slice::from_raw_parts((self.ptr() as *const T).add(offset), len) }
    }

    /// Mutable typed view.
    ///
    /// # Safety
    /// Same contract as [`Storage::slice`], plus exclusivity: no other
    /// reference (shared or mutable) may overlap the returned range.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut<T: Element>(&self, offset: usize, len: usize) -> &mut [T] {
        debug_assert!((offset + len) * std::mem::size_of::<T>() <= self.inner.block.size);
        // SAFETY: in-bounds, race-free and exclusive per this fn's contract.
        unsafe { std::slice::from_raw_parts_mut((self.ptr() as *mut T).add(offset), len) }
    }
}

impl std::fmt::Debug for Storage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Storage({} bytes, {}, refs={}, v{})",
            self.nbytes(),
            self.device(),
            self.ref_count(),
            self.version()
        )
    }
}

/// Raw pointer wrapper that may cross into stream-kernel closures. The
/// queued kernel holds the *pointer*, not a reference count — exactly the
/// paper's model where the host may logically free memory before the
/// device consumes it, made safe by FIFO streams + per-stream pools.
/// Stored as a `usize` address (not a raw pointer) so closures capturing it
/// are automatically `Send`/`Sync` and Rust-2021 disjoint field capture
/// cannot smuggle a bare `*mut u8` into a kernel closure.
#[derive(Clone, Copy)]
pub struct SendPtr(usize);

impl SendPtr {
    #[inline]
    pub fn new(p: *mut u8) -> SendPtr {
        SendPtr(p as usize)
    }
    /// The raw pointer.
    #[inline]
    pub fn ptr(&self) -> *mut u8 {
        self.0 as *mut u8
    }
    /// Typed const pointer.
    #[inline]
    pub fn as_f32(&self) -> *const f32 {
        self.0 as *const f32
    }
    /// Typed mut pointer.
    #[inline]
    pub fn as_f32_mut(&self) -> *mut f32 {
        self.0 as *mut f32
    }
    /// # Safety: caller guarantees bounds + no data race (stream FIFO).
    #[inline]
    pub unsafe fn as_slice<T: Element>(&self, offset: usize, len: usize) -> &'static [T] {
        // SAFETY: in-bounds and race-free per this fn's contract.
        unsafe { std::slice::from_raw_parts((self.0 as *const T).add(offset), len) }
    }
    /// # Safety: as `as_slice`, plus exclusivity of the written range.
    #[inline]
    pub unsafe fn as_mut_slice<T: Element>(&self, offset: usize, len: usize) -> &'static mut [T] {
        // SAFETY: in-bounds, race-free and exclusive per this fn's contract.
        unsafe { std::slice::from_raw_parts_mut((self.0 as *mut T).add(offset), len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::Allocator;

    #[test]
    fn from_slice_roundtrip() {
        let s = Storage::from_slice(&[1.0f32, 2.0, 3.0]);
        let back: &[f32] = unsafe { s.slice(0, 3) };
        assert_eq!(back, &[1.0, 2.0, 3.0]);
        assert_eq!(s.nbytes(), 12);
        assert_eq!(s.device(), Device::Cpu);
    }

    #[test]
    fn refcount_observability() {
        let s = Storage::from_slice(&[0.0f32; 8]);
        assert_eq!(s.ref_count(), 1);
        let s2 = s.clone();
        assert_eq!(s.ref_count(), 2);
        assert!(s.same_memory(&s2));
        drop(s2);
        assert_eq!(s.ref_count(), 1);
    }

    #[test]
    fn drop_returns_block_immediately() {
        // §5.5: memory must be released exactly when the last ref drops.
        let alloc = ctx::host_allocator();
        let before = alloc.stats();
        let s = Storage::new(1 << 16, Device::Cpu, StreamId::HOST);
        let during = alloc.stats();
        assert!(during.in_use_bytes >= before.in_use_bytes + (1 << 16));
        let s2 = s.clone();
        drop(s);
        // Still alive through s2.
        assert!(alloc.stats().in_use_bytes >= before.in_use_bytes + (1 << 16));
        drop(s2);
        assert_eq!(alloc.stats().in_use_bytes, before.in_use_bytes);
    }

    #[test]
    fn donation_taken_only_on_exact_match() {
        let s = Storage::from_slice(&[1.0f32; 256]); // 1024 bytes
        let ptr = s.ptr() as usize;
        arm_donation(s.clone());
        drop(s);
        // Mismatched size: not taken.
        let other = Storage::new(2048, Device::Cpu, StreamId::HOST);
        assert_ne!(other.ptr() as usize, ptr);
        // Exact (nbytes, device, stream) match: taken, same memory back.
        let reused = Storage::new(1024, Device::Cpu, StreamId::HOST);
        assert_eq!(reused.ptr() as usize, ptr);
        assert!(disarm_donation().is_none(), "slot must be consumed");
    }

    #[test]
    fn version_bumps() {
        let s = Storage::from_slice(&[1.0f32]);
        assert_eq!(s.version(), 0);
        s.bump_version();
        s.bump_version();
        assert_eq!(s.version(), 2);
        // Clones share the version counter (same memory => same version).
        let s2 = s.clone();
        s2.bump_version();
        assert_eq!(s.version(), 3);
    }

    #[test]
    fn i64_storage() {
        let s = Storage::from_slice(&[7i64, -3]);
        let v: &[i64] = unsafe { s.slice(0, 2) };
        assert_eq!(v, &[7, -3]);
    }

    #[test]
    fn slice_with_offset() {
        let s = Storage::from_slice(&[0.0f32, 1.0, 2.0, 3.0]);
        let tail: &[f32] = unsafe { s.slice(2, 2) };
        assert_eq!(tail, &[2.0, 3.0]);
    }
}
