//! Element types. The paper's benchmarks run in 32-bit floats (Table 1);
//! torsk computes in `f32` or `f64` and uses `i64` for indices/labels.
//! The dispatcher (see [`crate::dispatch`]) promotes mixed-dtype operands
//! with [`DType::promote`] before selecting a kernel instantiation.

/// Supported element types.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DType {
    /// 32-bit IEEE float — the default compute type.
    F32,
    /// 64-bit IEEE float — high-precision compute (gradcheck, science).
    F64,
    /// 64-bit signed integer — index/label type.
    I64,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
            DType::I64 => 8,
        }
    }

    /// Short display name (matches PyTorch's `torch.float32` style suffix).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::F64 => "float64",
            DType::I64 => "int64",
        }
    }

    /// Is this a floating-point type?
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F64)
    }

    /// Binary-op type promotion (NumPy-style, restricted to our lattice):
    /// `F64 > F32 > I64`. Mixed float/int promotes to the float type.
    pub fn promote(a: DType, b: DType) -> DType {
        fn rank(d: DType) -> u8 {
            match d {
                DType::I64 => 0,
                DType::F32 => 1,
                DType::F64 => 2,
            }
        }
        if rank(a) >= rank(b) {
            a
        } else {
            b
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Rust scalar types that correspond to a [`DType`]. The `from_f64`/`to_f64`
/// hooks let generic kernels (casts, scalar parameters) convert through a
/// common wide type without per-dtype special cases.
pub trait Element:
    Copy + Send + Sync + 'static + std::fmt::Debug + Default + PartialEq + PartialOrd
{
    const DTYPE: DType;
    /// Convert from a (possibly lossy) f64 — used by `cast` and scalar ops.
    fn from_f64(v: f64) -> Self;
    /// Widen to f64 — used by `cast` and host-side comparisons.
    fn to_f64(self) -> f64;
}

/// Floating [`Element`]s with the transcendental surface the fused
/// micro-op interpreter ([`crate::dispatch::fuse`]) needs. One generic
/// tape evaluator monomorphizes over this trait, so fused kernels run
/// identically (but at native precision) for F32 and F64.
pub trait FloatElement:
    Element
    + std::ops::Neg<Output = Self>
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
{
    const ZERO: Self;
    const ONE: Self;
    fn fexp(self) -> Self;
    fn fln(self) -> Self;
    fn fsqrt(self) -> Self;
    fn ftanh(self) -> Self;
    fn fmax(self, o: Self) -> Self;
    fn fmin(self, o: Self) -> Self;
}

impl FloatElement for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    #[inline(always)]
    fn fexp(self) -> f32 {
        self.exp()
    }
    #[inline(always)]
    fn fln(self) -> f32 {
        self.ln()
    }
    #[inline(always)]
    fn fsqrt(self) -> f32 {
        self.sqrt()
    }
    #[inline(always)]
    fn ftanh(self) -> f32 {
        self.tanh()
    }
    #[inline(always)]
    fn fmax(self, o: f32) -> f32 {
        self.max(o)
    }
    #[inline(always)]
    fn fmin(self, o: f32) -> f32 {
        self.min(o)
    }
}

impl FloatElement for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    #[inline(always)]
    fn fexp(self) -> f64 {
        self.exp()
    }
    #[inline(always)]
    fn fln(self) -> f64 {
        self.ln()
    }
    #[inline(always)]
    fn fsqrt(self) -> f64 {
        self.sqrt()
    }
    #[inline(always)]
    fn ftanh(self) -> f64 {
        self.tanh()
    }
    #[inline(always)]
    fn fmax(self, o: f64) -> f64 {
        self.max(o)
    }
    #[inline(always)]
    fn fmin(self, o: f64) -> f64 {
        self.min(o)
    }
}

impl Element for f32 {
    const DTYPE: DType = DType::F32;
    #[inline]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Element for f64 {
    const DTYPE: DType = DType::F64;
    #[inline]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
}

impl Element for i64 {
    const DTYPE: DType = DType::I64;
    #[inline]
    fn from_f64(v: f64) -> Self {
        v as i64
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::F64.size(), 8);
        assert_eq!(DType::I64.size(), 8);
    }

    #[test]
    fn element_mapping() {
        assert_eq!(<f32 as Element>::DTYPE, DType::F32);
        assert_eq!(<f64 as Element>::DTYPE, DType::F64);
        assert_eq!(<i64 as Element>::DTYPE, DType::I64);
    }

    #[test]
    fn display() {
        assert_eq!(DType::F32.to_string(), "float32");
        assert_eq!(DType::F64.to_string(), "float64");
    }

    #[test]
    fn promotion_lattice() {
        assert_eq!(DType::promote(DType::F32, DType::F64), DType::F64);
        assert_eq!(DType::promote(DType::F64, DType::F32), DType::F64);
        assert_eq!(DType::promote(DType::I64, DType::F32), DType::F32);
        assert_eq!(DType::promote(DType::I64, DType::I64), DType::I64);
    }

    #[test]
    fn element_f64_roundtrip() {
        assert_eq!(f32::from_f64(1.5).to_f64(), 1.5);
        assert_eq!(i64::from_f64(3.9), 3);
        assert!(DType::F64.is_float() && !DType::I64.is_float());
    }
}
