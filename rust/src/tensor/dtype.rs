//! Element types. The paper's benchmarks run in 32-bit floats (Table 1);
//! torsk supports `f32` compute plus `i64` indices (labels, embeddings).

/// Supported element types.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DType {
    /// 32-bit IEEE float — the compute type.
    F32,
    /// 64-bit signed integer — index/label type.
    I64,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::I64 => 8,
        }
    }

    /// Short display name (matches PyTorch's `torch.float32` style suffix).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I64 => "int64",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Rust scalar types that correspond to a [`DType`].
pub trait Element: Copy + Send + Sync + 'static + std::fmt::Debug + Default + PartialEq {
    const DTYPE: DType;
}

impl Element for f32 {
    const DTYPE: DType = DType::F32;
}

impl Element for i64 {
    const DTYPE: DType = DType::I64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::I64.size(), 8);
    }

    #[test]
    fn element_mapping() {
        assert_eq!(<f32 as Element>::DTYPE, DType::F32);
        assert_eq!(<i64 as Element>::DTYPE, DType::I64);
    }

    #[test]
    fn display() {
        assert_eq!(DType::F32.to_string(), "float32");
    }
}
