//! Shape / stride arithmetic: contiguity, broadcasting (NumPy semantics,
//! §4.2 interoperability), and index iteration for strided views.

/// Number of elements for a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Row-major (C) contiguous strides for a shape, in elements.
pub fn contiguous_strides(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![0; shape.len()];
    let mut acc = 1usize;
    for i in (0..shape.len()).rev() {
        strides[i] = acc;
        acc *= shape[i].max(1);
    }
    strides
}

/// Whether (shape, strides) describes a dense row-major layout.
pub fn is_contiguous(shape: &[usize], strides: &[usize]) -> bool {
    let mut acc = 1usize;
    for i in (0..shape.len()).rev() {
        if shape[i] != 1 && strides[i] != acc {
            return false;
        }
        acc *= shape[i].max(1);
    }
    true
}

/// NumPy-style broadcast of two shapes. Panics on incompatibility — eager
/// fail-fast semantics (see crate::error).
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Vec<usize> {
    match try_broadcast_shapes(a, b) {
        Some(s) => s,
        None => crate::torsk_bail!(
            "shapes {:?} and {:?} are not broadcastable",
            a,
            b
        ),
    }
}

/// Broadcast two shapes, returning `None` on incompatibility.
pub fn try_broadcast_shapes(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let n = a.len().max(b.len());
    let mut out = vec![0; n];
    for i in 0..n {
        let da = if i < n - a.len() { 1 } else { a[i - (n - a.len())] };
        let db = if i < n - b.len() { 1 } else { b[i - (n - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            return None;
        };
    }
    Some(out)
}

/// Strides to read a tensor of shape `from` as the broadcast shape `to`
/// (stride 0 on expanded axes). `from` must be broadcastable to `to`.
pub fn broadcast_strides(from: &[usize], strides: &[usize], to: &[usize]) -> Vec<usize> {
    debug_assert_eq!(from.len(), strides.len());
    let offset = to.len() - from.len();
    let mut out = vec![0usize; to.len()];
    for i in 0..from.len() {
        let t = offset + i;
        if from[i] == to[t] {
            out[t] = strides[i];
        } else if from[i] == 1 {
            out[t] = 0;
        } else {
            crate::torsk_bail!("cannot broadcast axis {i}: {} -> {}", from[i], to[t]);
        }
    }
    out
}

/// Axes of `grad_shape` that were broadcast from `orig_shape` and must be
/// sum-reduced when propagating gradients through a broadcast op.
/// Returns (leading axes to sum away, axes to sum keeping dim).
pub fn reduce_axes_for_broadcast(orig_shape: &[usize], grad_shape: &[usize]) -> (Vec<usize>, Vec<usize>) {
    let lead = grad_shape.len() - orig_shape.len();
    let leading: Vec<usize> = (0..lead).collect();
    let mut keepdim = vec![];
    for (i, &d) in orig_shape.iter().enumerate() {
        if d == 1 && grad_shape[lead + i] != 1 {
            keepdim.push(lead + i);
        }
    }
    (leading, keepdim)
}

/// Convert a linear index (row-major over `shape`) into a storage offset
/// using `strides`.
#[inline]
pub fn linear_to_offset(mut lin: usize, shape: &[usize], strides: &[usize]) -> usize {
    let mut off = 0;
    for i in (0..shape.len()).rev() {
        let d = shape[i];
        if d > 0 {
            off += (lin % d) * strides[i];
            lin /= d;
        }
    }
    off
}

/// Iterator over storage offsets of a strided view in row-major order.
/// Specialized fast paths live in the kernels; this is the generic one.
pub struct StridedIter<'a> {
    shape: &'a [usize],
    strides: &'a [usize],
    index: Vec<usize>,
    offset: usize,
    remaining: usize,
}

impl<'a> StridedIter<'a> {
    pub fn new(shape: &'a [usize], strides: &'a [usize]) -> Self {
        StridedIter {
            shape,
            strides,
            index: vec![0; shape.len()],
            offset: 0,
            remaining: numel(shape),
        }
    }

    /// Iterator over `count` offsets beginning at linear position `start`
    /// (row-major over `shape`). This is what lets parallel kernels hand
    /// each worker a disjoint `[start, start+count)` slice of an odometer
    /// walk without replaying the prefix.
    pub fn starting_at(shape: &'a [usize], strides: &'a [usize], start: usize, count: usize) -> Self {
        let mut index = vec![0; shape.len()];
        let mut lin = start;
        for i in (0..shape.len()).rev() {
            let d = shape[i];
            if d > 0 {
                index[i] = lin % d;
                lin /= d;
            }
        }
        StridedIter {
            shape,
            strides,
            index,
            offset: linear_to_offset(start, shape, strides),
            remaining: count.min(numel(shape).saturating_sub(start)),
        }
    }
}

impl<'a> Iterator for StridedIter<'a> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.remaining == 0 {
            return None;
        }
        let out = self.offset;
        self.remaining -= 1;
        // Odometer increment.
        for i in (0..self.shape.len()).rev() {
            self.index[i] += 1;
            self.offset += self.strides[i];
            if self.index[i] < self.shape[i] {
                break;
            }
            self.offset -= self.index[i] * self.strides[i];
            self.index[i] = 0;
        }
        Some(out)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_strides_row_major() {
        assert_eq!(contiguous_strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(contiguous_strides(&[5]), vec![1]);
        assert_eq!(contiguous_strides(&[]), Vec::<usize>::new());
    }

    #[test]
    fn contiguity_checks() {
        assert!(is_contiguous(&[2, 3], &[3, 1]));
        assert!(!is_contiguous(&[2, 3], &[1, 2])); // transposed
        assert!(is_contiguous(&[1, 3], &[99, 1])); // size-1 dims don't matter
        assert!(is_contiguous(&[], &[]));
    }

    #[test]
    fn broadcast_basic() {
        assert_eq!(broadcast_shapes(&[2, 3], &[3]), vec![2, 3]);
        assert_eq!(broadcast_shapes(&[2, 1, 4], &[3, 1]), vec![2, 3, 4]);
        assert_eq!(broadcast_shapes(&[], &[2, 2]), vec![2, 2]);
        assert_eq!(try_broadcast_shapes(&[2, 3], &[2, 4]), None);
    }

    #[test]
    #[should_panic(expected = "broadcastable")]
    fn broadcast_incompatible_panics() {
        broadcast_shapes(&[2, 3], &[4, 3, 2]);
    }

    #[test]
    fn broadcast_strides_zeroes_expanded_axes() {
        let s = broadcast_strides(&[3, 1], &[1, 1], &[2, 3, 4]);
        assert_eq!(s, vec![0, 1, 0]);
    }

    #[test]
    fn reduce_axes() {
        let (lead, keep) = reduce_axes_for_broadcast(&[3, 1], &[2, 3, 4]);
        assert_eq!(lead, vec![0]);
        assert_eq!(keep, vec![2]);
        let (lead, keep) = reduce_axes_for_broadcast(&[2, 3], &[2, 3]);
        assert!(lead.is_empty() && keep.is_empty());
    }

    #[test]
    fn strided_iter_matches_linear_for_contiguous() {
        let shape = [2usize, 3, 2];
        let strides = contiguous_strides(&shape);
        let offs: Vec<usize> = StridedIter::new(&shape, &strides).collect();
        assert_eq!(offs, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn strided_iter_transposed() {
        // 2x3 transposed view of a 3x2 buffer: strides [1, 2].
        let shape = [2usize, 3];
        let strides = [1usize, 2];
        let offs: Vec<usize> = StridedIter::new(&shape, &strides).collect();
        assert_eq!(offs, vec![0, 2, 4, 1, 3, 5]);
    }

    #[test]
    fn starting_at_matches_full_walk_in_chunks() {
        let shape = [3usize, 4, 5];
        let strides = [1usize, 15, 3]; // deliberately permuted layout
        let full: Vec<usize> = StridedIter::new(&shape, &strides).collect();
        for chunk in [1usize, 7, 16, 60, 100] {
            let mut got = vec![];
            let mut s = 0;
            while s < 60 {
                got.extend(StridedIter::starting_at(&shape, &strides, s, chunk));
                s += chunk;
            }
            assert_eq!(got, full, "chunk={chunk}");
        }
        // Starting past the end yields nothing.
        assert_eq!(StridedIter::starting_at(&shape, &strides, 60, 5).count(), 0);
    }

    #[test]
    fn linear_to_offset_agrees_with_iter() {
        let shape = [3usize, 4, 5];
        let strides = [40usize, 5, 1]; // padded layout
        let offs: Vec<usize> = StridedIter::new(&shape, &strides).collect();
        for (lin, &off) in offs.iter().enumerate() {
            assert_eq!(linear_to_offset(lin, &shape, &strides), off);
        }
    }
}
