//! Durable training checkpoints (ARCHITECTURE.md §7).
//!
//! A [`Checkpoint`] captures everything a training loop needs to resume
//! *bitwise*: the model's `state_dict` tensors, the optimizer's state
//! dict ([`crate::optim::OptimStateDict`] — momenta, Adam step count),
//! the RNG coordinates (the global seed plus an optional explicit
//! [`Rng`](crate::rng::Rng) stream position), and the [`DataLoader`]
//! replay coordinate `(seed, epoch, next batch)`. Resume wiring:
//! `Module::load_state_dict` + `Optimizer::load_state_dict` +
//! `rng::manual_seed` + [`crate::data::DataLoader::resume`], after which
//! the remaining batch schedule replays exactly — `tests/chaos.rs` pins
//! kill-and-resume runs bitwise against uninterrupted ones.
//!
//! On-disk layout (all little-endian):
//!
//! ```text
//! [magic u32][version u32][payload_len u64][payload crc32 u32][payload]
//! ```
//!
//! Durability protocol: [`Checkpoint::save`] writes a sibling temp file
//! (`<name>.tmp.<pid>`), fsyncs it, renames it over the target, then
//! fsyncs the directory — readers see the old file or the new file,
//! never a partial one, and a failed save cleans up its temp file.
//! [`Checkpoint::load`] verifies magic, version, length, and CRC before
//! decoding; anything off is a typed [`TorskError::Corrupt`] with the
//! byte offset, never a panic and never a silently short state dict.

pub mod format;

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::error::{Result, TorskError};
use crate::optim::{OptimStateDict, Optimizer};
use crate::tensor::Tensor;
use crate::testing::chaos;
use crate::torsk_bail;

use format::{crc32, Reader, Writer};

/// `b"TSK1"` as a little-endian u32.
const MAGIC: u32 = u32::from_le_bytes(*b"TSK1");
const VERSION: u32 = 1;
/// magic + version + payload_len + crc.
const HEADER_LEN: usize = 4 + 4 + 8 + 4;

/// Chaos fault point: arm [`chaos::Fault::FailWriteAfter`] here to make
/// [`Checkpoint::save`] fail after writing N bytes of the temp file.
pub const FAULT_WRITE: &str = "checkpoint:write";

/// Where a [`DataLoader`] was when the checkpoint was taken: re-planning
/// the epoch from `(seed, epoch)` and skipping `next_batch` batches
/// replays the exact remaining schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoaderState {
    /// The loader's sampler seed.
    pub seed: u64,
    /// The epoch being iterated when the checkpoint was taken.
    pub epoch: u64,
    /// Index of the first batch the resumed run should yield.
    pub next_batch: u64,
}

/// A complete, resumable training snapshot. Build with [`Checkpoint::new`]
/// plus the `with_*` methods, persist with [`Checkpoint::save`], restore
/// with [`Checkpoint::load`].
pub struct Checkpoint {
    /// Model parameters and buffers (`Module::state_dict`).
    pub model: BTreeMap<String, Tensor>,
    /// Optimizer state, if an optimizer rides along.
    pub optim: Option<OptimStateDict>,
    /// The global RNG seed at save time (`rng::global_seed`); restore
    /// with `rng::manual_seed`.
    pub global_seed: u64,
    /// An explicit RNG stream position ([`crate::rng::Rng::state`]), for
    /// loops that thread their own generator.
    pub rng_stream: Option<[u64; 4]>,
    /// DataLoader replay coordinate.
    pub loader: Option<LoaderState>,
}

impl Checkpoint {
    /// Start a checkpoint from a model state dict; captures the current
    /// global seed.
    pub fn new(model: BTreeMap<String, Tensor>) -> Checkpoint {
        Checkpoint {
            model,
            optim: None,
            global_seed: crate::rng::global_seed(),
            rng_stream: None,
            loader: None,
        }
    }

    /// Snapshot `opt`'s state into the checkpoint.
    pub fn with_optimizer(mut self, opt: &dyn Optimizer) -> Checkpoint {
        self.optim = Some(opt.state_dict());
        self
    }

    /// Record the loader replay coordinate.
    pub fn with_loader(mut self, state: LoaderState) -> Checkpoint {
        self.loader = Some(state);
        self
    }

    /// Record an explicit RNG stream position.
    pub fn with_rng_stream(mut self, state: [u64; 4]) -> Checkpoint {
        self.rng_stream = Some(state);
        self
    }

    fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        // Model section.
        w.put_u64(self.model.len() as u64);
        for (name, t) in &self.model {
            w.put_str(name);
            w.put_tensor(t);
        }
        // Optimizer section.
        match &self.optim {
            None => w.put_u8(0),
            Some(sd) => {
                w.put_u8(1);
                w.put_str(&sd.kind);
                w.put_u64(sd.step);
                w.put_u64(sd.hypers.len() as u64);
                for (name, &v) in &sd.hypers {
                    w.put_str(name);
                    w.put_f32(v);
                }
                w.put_u64(sd.tensors.len() as u64);
                for (name, t) in &sd.tensors {
                    w.put_str(name);
                    w.put_tensor(t);
                }
            }
        }
        // RNG section.
        w.put_u64(self.global_seed);
        match self.rng_stream {
            None => w.put_u8(0),
            Some(s) => {
                w.put_u8(1);
                for v in s {
                    w.put_u64(v);
                }
            }
        }
        // Loader section.
        match self.loader {
            None => w.put_u8(0),
            Some(ls) => {
                w.put_u8(1);
                w.put_u64(ls.seed);
                w.put_u64(ls.epoch);
                w.put_u64(ls.next_batch);
            }
        }
        w.into_bytes()
    }

    fn decode(r: &mut Reader<'_>) -> Result<Checkpoint> {
        let n_model = r.u64()? as usize;
        let mut model = BTreeMap::new();
        for _ in 0..n_model {
            let name = r.str()?;
            let t = r.tensor()?;
            model.insert(name, t);
        }
        let optim = if r.u8()? != 0 {
            let kind = r.str()?;
            let step = r.u64()?;
            let n_hypers = r.u64()? as usize;
            let mut hypers = BTreeMap::new();
            for _ in 0..n_hypers {
                let name = r.str()?;
                let v = r.f32()?;
                hypers.insert(name, v);
            }
            let n_tensors = r.u64()? as usize;
            let mut tensors = BTreeMap::new();
            for _ in 0..n_tensors {
                let name = r.str()?;
                let t = r.tensor()?;
                tensors.insert(name, t);
            }
            Some(OptimStateDict { kind, step, hypers, tensors })
        } else {
            None
        };
        let global_seed = r.u64()?;
        let rng_stream = if r.u8()? != 0 {
            Some([r.u64()?, r.u64()?, r.u64()?, r.u64()?])
        } else {
            None
        };
        let loader = if r.u8()? != 0 {
            Some(LoaderState { seed: r.u64()?, epoch: r.u64()?, next_batch: r.u64()? })
        } else {
            None
        };
        if !r.is_empty() {
            return Err(r.corrupt("trailing bytes after checkpoint", 0, r.remaining() as u64));
        }
        Ok(Checkpoint { model, optim, global_seed, rng_stream, loader })
    }

    /// Serialize to `path` atomically: temp file → fsync → rename →
    /// directory fsync. On any failure the temp file is removed and the
    /// previous checkpoint at `path` (if any) is left untouched.
    pub fn save(&self, path: &Path) -> Result<()> {
        let payload = self.encode();
        let mut bytes = Vec::with_capacity(HEADER_LEN + payload.len());
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&crc32(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);
        atomic_write(path, &bytes)
    }

    /// Load and fully validate a checkpoint. Returns
    /// [`TorskError::Io`] if the file cannot be read and
    /// [`TorskError::Corrupt`] (with byte offset) on any structural
    /// failure: bad magic, version skew, torn payload, checksum mismatch.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path).map_err(|e| TorskError::io("read checkpoint", path, e))?;
        let corrupt = |offset: u64, what: &str, expected: u64, found: u64| TorskError::Corrupt {
            path: path.to_path_buf(),
            offset,
            what: what.to_string(),
            expected,
            found,
        };
        if bytes.len() < HEADER_LEN {
            return Err(corrupt(0, "truncated header", HEADER_LEN as u64, bytes.len() as u64));
        }
        let magic = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        if magic != MAGIC {
            return Err(corrupt(0, "bad magic", MAGIC as u64, magic as u64));
        }
        let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
        if version != VERSION {
            return Err(corrupt(4, "unsupported version", VERSION as u64, version as u64));
        }
        let payload_len = u64::from_le_bytes([
            bytes[8], bytes[9], bytes[10], bytes[11], bytes[12], bytes[13], bytes[14], bytes[15],
        ]);
        let stored_crc = u32::from_le_bytes([bytes[16], bytes[17], bytes[18], bytes[19]]);
        let payload = &bytes[HEADER_LEN..];
        if payload.len() as u64 != payload_len {
            // A torn write truncates here: the header promises more
            // payload than survived.
            return Err(corrupt(8, "payload length mismatch", payload_len, payload.len() as u64));
        }
        let computed = crc32(payload);
        if computed != stored_crc {
            return Err(corrupt(16, "checksum mismatch", stored_crc as u64, computed as u64));
        }
        let mut r = Reader::new(payload, path, HEADER_LEN as u64);
        Checkpoint::decode(&mut r)
    }
}

/// Write `bytes` to `path` atomically via a sibling temp file. The
/// [`FAULT_WRITE`] chaos point can truncate the write partway to model a
/// crash or disk-full mid-save.
fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let name = match path.file_name() {
        Some(n) => n.to_string_lossy().into_owned(),
        None => torsk_bail!("checkpoint path has no file name: {}", path.display()),
    };
    // Same directory as the target: rename(2) is only atomic within a
    // filesystem. The pid suffix keeps concurrent savers from colliding.
    let tmp = path.with_file_name(format!("{name}.tmp.{}", std::process::id()));
    let result = write_and_rename(&tmp, path, bytes);
    if result.is_err() {
        // Best-effort cleanup: never leave a partial temp file behind.
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

fn write_and_rename(tmp: &Path, path: &Path, bytes: &[u8]) -> Result<()> {
    let mut f =
        File::create(tmp).map_err(|e| TorskError::io("create checkpoint temp file", tmp, e))?;
    if let Some(budget) = chaos::write_fault(FAULT_WRITE) {
        // Injected torn write: emit at most `budget` bytes, then fail as
        // a disk-full would.
        let partial = &bytes[..budget.min(bytes.len())];
        f.write_all(partial).map_err(|e| TorskError::io("write checkpoint", tmp, e))?;
        let _ = f.sync_all();
        return Err(TorskError::io(
            "write checkpoint",
            tmp,
            std::io::Error::other(format!("chaos: write failed after {} bytes", partial.len())),
        ));
    }
    f.write_all(bytes).map_err(|e| TorskError::io("write checkpoint", tmp, e))?;
    // fsync before rename: otherwise the rename can land while the data
    // has not, and a crash leaves a valid-looking empty file.
    f.sync_all().map_err(|e| TorskError::io("sync checkpoint", tmp, e))?;
    drop(f);
    std::fs::rename(tmp, path)
        .map_err(|e| TorskError::io("rename checkpoint into place", tmp, e))?;
    // fsync the directory so the rename itself is durable.
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static NEXT_FILE: AtomicUsize = AtomicUsize::new(0);

    /// A unique scratch path per call (tests run concurrently in one
    /// process, and the suite may share a machine with another run).
    fn scratch(tag: &str) -> PathBuf {
        let n = NEXT_FILE.fetch_add(1, Ordering::SeqCst);
        std::env::temp_dir().join(format!("torsk-ckpt-{}-{n}-{tag}.bin", std::process::id()))
    }

    fn sample_model() -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), Tensor::from_vec(vec![1.0f32, -2.5, 3.25, 0.5], &[2, 2]));
        m.insert("b64".to_string(), Tensor::from_vec(vec![0.1f64, 0.2], &[2]));
        m.insert("steps".to_string(), Tensor::from_vec(vec![7i64], &[1]));
        m
    }

    fn assert_bitwise_eq(a: &Tensor, b: &Tensor) {
        assert_eq!(a.dtype(), b.dtype());
        assert_eq!(a.shape(), b.shape());
        match a.dtype() {
            crate::tensor::DType::F32 => assert_eq!(
                a.to_vec::<f32>().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.to_vec::<f32>().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            ),
            crate::tensor::DType::F64 => assert_eq!(
                a.to_vec::<f64>().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.to_vec::<f64>().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            ),
            crate::tensor::DType::I64 => assert_eq!(a.to_vec::<i64>(), b.to_vec::<i64>()),
        }
    }

    #[test]
    fn full_checkpoint_round_trips_bitwise() {
        let path = scratch("full");
        let mut rng = Rng::new(31);
        for _ in 0..5 {
            rng.next_u64();
        }
        let mut hypers = BTreeMap::new();
        hypers.insert("lr".to_string(), 1e-3);
        let mut tensors = BTreeMap::new();
        tensors.insert("m.0".to_string(), Tensor::from_vec(vec![0.25f32, -0.5], &[2]));
        let optim = OptimStateDict { kind: "adam".to_string(), step: 12, hypers, tensors };

        let ckpt = Checkpoint {
            model: sample_model(),
            optim: Some(optim),
            global_seed: 0xFEED,
            rng_stream: Some(rng.state()),
            loader: Some(LoaderState { seed: 9, epoch: 3, next_batch: 4 }),
        };
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();

        assert_eq!(back.model.len(), 3);
        for (name, t) in &ckpt.model {
            assert_bitwise_eq(t, &back.model[name]);
        }
        let bo = back.optim.as_ref().unwrap();
        assert_eq!(bo.kind, "adam");
        assert_eq!(bo.step, 12);
        assert_eq!(bo.hypers["lr"], 1e-3);
        assert_bitwise_eq(&ckpt.optim.as_ref().unwrap().tensors["m.0"], &bo.tensors["m.0"]);
        assert_eq!(back.global_seed, 0xFEED);
        // The restored stream continues exactly where the saved one was.
        let mut resumed = Rng::from_state(back.rng_stream.unwrap());
        assert_eq!(resumed.next_u64(), rng.next_u64());
        assert_eq!(back.loader, Some(LoaderState { seed: 9, epoch: 3, next_batch: 4 }));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn minimal_checkpoint_round_trips() {
        // Step-0 shape: no optimizer state, no loader, no explicit stream.
        let path = scratch("minimal");
        let ckpt = Checkpoint::new(sample_model());
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert!(back.optim.is_none());
        assert!(back.rng_stream.is_none());
        assert!(back.loader.is_none());
        assert_eq!(back.global_seed, ckpt.global_seed);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn save_overwrites_atomically() {
        let path = scratch("overwrite");
        let mut m1 = BTreeMap::new();
        m1.insert("w".to_string(), Tensor::from_vec(vec![1.0f32], &[1]));
        Checkpoint::new(m1).save(&path).unwrap();
        let mut m2 = BTreeMap::new();
        m2.insert("w".to_string(), Tensor::from_vec(vec![2.0f32], &[1]));
        Checkpoint::new(m2).save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.model["w"].to_vec::<f32>(), vec![2.0]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_of_missing_file_is_io_error() {
        let err = Checkpoint::load(Path::new("/nonexistent/torsk.ckpt")).unwrap_err();
        assert!(matches!(err, TorskError::Io { op: "read checkpoint", .. }), "{err}");
    }

    #[test]
    fn corrupt_payload_byte_fails_checksum() {
        let path = scratch("bitrot");
        Checkpoint::new(sample_model()).save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40; // flip one payload bit
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        match err {
            TorskError::Corrupt { offset, ref what, .. } => {
                assert_eq!(what, "checksum mismatch");
                assert_eq!(offset, 16, "checksum lives at byte 16 of the header");
            }
            other => panic!("expected Corrupt, got {other}"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_file_reports_payload_length_mismatch() {
        let path = scratch("torn");
        Checkpoint::new(sample_model()).save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(
            matches!(err, TorskError::Corrupt { ref what, .. }
                if what == "payload length mismatch"),
            "{err}"
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn wrong_magic_is_rejected_before_any_decode() {
        let path = scratch("magic");
        std::fs::write(&path, b"definitely not a torsk checkpoint, but long enough").unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(
            matches!(err, TorskError::Corrupt { offset: 0, ref what, .. } if what == "bad magic"),
            "{err}"
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn future_version_is_rejected() {
        let path = scratch("version");
        Checkpoint::new(sample_model()).save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99; // version field
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(
            matches!(err, TorskError::Corrupt { offset: 4, ref what, .. }
                if what == "unsupported version"),
            "{err}"
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn injected_write_failure_leaves_no_partial_file() {
        let path = scratch("chaos-write");
        // A prior good checkpoint must survive the failed save.
        Checkpoint::new(sample_model()).save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();

        chaos::arm(FAULT_WRITE, chaos::Fault::FailWriteAfter(10));
        let err = Checkpoint::new(sample_model()).save(&path).unwrap_err();
        chaos::disarm(FAULT_WRITE);
        assert!(matches!(err, TorskError::Io { .. }), "{err}");

        // Target intact, temp file cleaned up.
        assert_eq!(std::fs::read(&path).unwrap(), good);
        let dir = path.parent().unwrap();
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let leftovers: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with(&name) && n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "partial temp files left behind: {leftovers:?}");
        // The surviving checkpoint still loads cleanly.
        Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
    }
}
