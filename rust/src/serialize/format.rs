//! The checkpoint wire format: a hand-rolled little-endian binary
//! encoding (no serde in the offline crate set, mirroring the hand-rolled
//! bench JSON schemas) plus the CRC-32 used for torn-write detection.
//!
//! Primitives: `u8`/`u32`/`u64` little-endian; `f32` as its IEEE-754 bit
//! pattern (`to_bits`), so values — including NaNs — round-trip bitwise;
//! strings as `u64` length + UTF-8 bytes; tensors as
//! `[dtype tag u8][rank u64][dims u64…][elements LE]`. Non-contiguous
//! tensors are materialized on encode (`to_vec` walks the strides), so a
//! transposed parameter view saves and restores as its logical contents.
//!
//! [`Reader`] never panics on malformed input: every decode failure is a
//! typed [`TorskError::Corrupt`] carrying the file path and the absolute
//! byte offset where validation failed.

use std::path::Path;

use crate::error::{Result, TorskError};
use crate::tensor::{DType, Tensor};
use crate::torsk_assert;

/// Rank cap for decoded tensors — no torsk workload exceeds it, and it
/// bounds the damage a corrupt rank field can do.
const MAX_RANK: usize = 8;

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3 polynomial, reflected), the same checksum as
/// gzip/zlib: cheap, and torn writes — the failure it exists to catch —
/// are truncations or zero runs, which it detects reliably.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

fn dtype_tag(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F64 => 1,
        DType::I64 => 2,
    }
}

/// Append-only payload encoder.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Store the IEEE-754 bit pattern: bitwise round-trip, NaNs included.
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Encode a host tensor; non-contiguous views are materialized here
    /// (`to_vec` walks the strides), so what is stored is the logical
    /// row-major contents.
    pub fn put_tensor(&mut self, t: &Tensor) {
        torsk_assert!(
            t.device() == crate::device::Device::Cpu,
            "serialize: checkpoint tensors must live on the host"
        );
        self.put_u8(dtype_tag(t.dtype()));
        self.put_u64(t.ndim() as u64);
        for &d in t.shape() {
            self.put_u64(d as u64);
        }
        match t.dtype() {
            DType::F32 => {
                for v in t.to_vec::<f32>() {
                    self.buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            DType::F64 => {
                for v in t.to_vec::<f64>() {
                    self.buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            DType::I64 => {
                for v in t.to_vec::<i64>() {
                    self.buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Payload decoder with positioned, typed failure: every error is a
/// [`TorskError::Corrupt`] naming the file and the absolute byte offset.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    path: &'a Path,
    /// File offset of `buf[0]` (the payload sits after the header), so
    /// reported offsets are absolute file positions.
    base: u64,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8], path: &'a Path, base: u64) -> Reader<'a> {
        Reader { buf, pos: 0, path, base }
    }

    /// A [`TorskError::Corrupt`] at the current position.
    pub fn corrupt(&self, what: &str, expected: u64, found: u64) -> TorskError {
        TorskError::Corrupt {
            path: self.path.to_path_buf(),
            offset: self.base + self.pos as u64,
            what: what.to_string(),
            expected,
            found,
        }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(self.corrupt("truncated record", n as u64, self.remaining() as u64));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| {
            self.corrupt("invalid utf-8 in string", 0, e.utf8_error().valid_up_to() as u64)
        })
    }

    pub fn tensor(&mut self) -> Result<Tensor> {
        let tag = self.u8()?;
        let dtype = match tag {
            0 => DType::F32,
            1 => DType::F64,
            2 => DType::I64,
            other => return Err(self.corrupt("unknown dtype tag", 2, other as u64)),
        };
        let ndim = self.u64()? as usize;
        if ndim > MAX_RANK {
            return Err(self.corrupt("implausible tensor rank", MAX_RANK as u64, ndim as u64));
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut numel: usize = 1;
        for _ in 0..ndim {
            let d = self.u64()? as usize;
            match numel.checked_mul(d) {
                Some(n) => numel = n,
                None => return Err(self.corrupt("tensor shape overflows", u64::MAX, d as u64)),
            }
            shape.push(d);
        }
        // Bounds-check the element count against the bytes actually
        // present *before* allocating: a corrupt dim must not trigger a
        // multi-gigabyte allocation.
        let nbytes = match numel.checked_mul(dtype.size()) {
            Some(n) => n,
            None => return Err(self.corrupt("tensor size overflows", u64::MAX, numel as u64)),
        };
        let bytes = self.take(nbytes)?;
        Ok(match dtype {
            DType::F32 => {
                let data: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::from_vec(data, &shape)
            }
            DType::F64 => {
                let data: Vec<f64> = bytes
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
                    .collect();
                Tensor::from_vec(data, &shape)
            }
            DType::I64 => {
                let data: Vec<i64> = bytes
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
                    .collect();
                Tensor::from_vec(data, &shape)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;

    fn path() -> PathBuf {
        PathBuf::from("/test/fake.ckpt")
    }

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // The standard check vector for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_detects_single_bit_flips_and_truncation() {
        let data = b"the quick brown fox jumps over the lazy dog".to_vec();
        let good = crc32(&data);
        let mut flipped = data.clone();
        flipped[7] ^= 0x10;
        assert_ne!(crc32(&flipped), good);
        assert_ne!(crc32(&data[..data.len() - 1]), good);
    }

    #[test]
    fn primitives_round_trip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f32(-0.0);
        w.put_f32(f32::NAN);
        w.put_str("velocity.3");
        w.put_str("");
        let bytes = w.into_bytes();
        let p = path();
        let mut r = Reader::new(&bytes, &p, 0);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        // Bitwise round-trip: -0.0 keeps its sign bit, NaN its payload.
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.f32().unwrap().is_nan());
        assert_eq!(r.str().unwrap(), "velocity.3");
        assert_eq!(r.str().unwrap(), "");
        assert!(r.is_empty());
    }

    #[test]
    fn tensors_round_trip_across_dtypes() {
        let p = path();
        for t in [
            Tensor::from_vec(vec![1.5f32, -2.0, 3.25, 0.0], &[2, 2]),
            Tensor::from_vec(vec![1.5f64, f64::MIN_POSITIVE, -7.0], &[3]),
            Tensor::from_vec(vec![i64::MIN, 0, i64::MAX], &[3, 1]),
            Tensor::from_vec(vec![42.0f32], &[]),
        ] {
            let mut w = Writer::new();
            w.put_tensor(&t);
            let bytes = w.into_bytes();
            let back = Reader::new(&bytes, &p, 0).tensor().unwrap();
            assert_eq!(back.dtype(), t.dtype());
            assert_eq!(back.shape(), t.shape());
            match t.dtype() {
                DType::F32 => assert_eq!(
                    back.to_vec::<f32>().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    t.to_vec::<f32>().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                ),
                DType::F64 => assert_eq!(
                    back.to_vec::<f64>().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    t.to_vec::<f64>().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                ),
                DType::I64 => assert_eq!(back.to_vec::<i64>(), t.to_vec::<i64>()),
            }
        }
    }

    #[test]
    fn non_contiguous_views_materialize_on_encode() {
        let m = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let view = m.t(); // [3, 2] strided view
        let mut w = Writer::new();
        w.put_tensor(&view);
        let bytes = w.into_bytes();
        let p = path();
        let back = Reader::new(&bytes, &p, 0).tensor().unwrap();
        assert_eq!(back.shape(), &[3, 2]);
        assert_eq!(back.to_vec::<f32>(), vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn truncated_input_is_a_typed_corrupt_error() {
        let mut w = Writer::new();
        w.put_tensor(&Tensor::from_vec(vec![1.0f32, 2.0], &[2]));
        let bytes = w.into_bytes();
        let p = path();
        let err = Reader::new(&bytes[..bytes.len() - 3], &p, 100).tensor().unwrap_err();
        match err {
            TorskError::Corrupt { offset, ref what, .. } => {
                assert!(what.contains("truncated"), "{what}");
                // Offsets are absolute: base 100 + position within payload.
                assert!(offset >= 100, "offset={offset}");
            }
            other => panic!("expected Corrupt, got {other}"),
        }
    }

    #[test]
    fn bad_dtype_tag_is_rejected() {
        let p = path();
        let err = Reader::new(&[9u8], &p, 0).tensor().unwrap_err();
        assert!(matches!(err, TorskError::Corrupt { found: 9, .. }), "{err}");
    }

    #[test]
    fn huge_corrupt_shape_fails_without_allocating() {
        let mut w = Writer::new();
        w.put_u8(0); // f32
        w.put_u64(2);
        w.put_u64(u64::MAX / 2); // absurd dim
        w.put_u64(4);
        let bytes = w.into_bytes();
        let p = path();
        let err = Reader::new(&bytes, &p, 0).tensor().unwrap_err();
        assert!(matches!(err, TorskError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn implausible_rank_is_rejected() {
        let mut w = Writer::new();
        w.put_u8(0);
        w.put_u64(1000); // rank 1000
        let bytes = w.into_bytes();
        let p = path();
        let err = Reader::new(&bytes, &p, 0).tensor().unwrap_err();
        match err {
            TorskError::Corrupt { ref what, found, .. } => {
                assert!(what.contains("rank"), "{what}");
                assert_eq!(found, 1000);
            }
            other => panic!("expected Corrupt, got {other}"),
        }
    }
}
