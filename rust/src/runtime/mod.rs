//! PJRT/XLA runtime: loads AOT-compiled HLO-text artifacts produced by the
//! Python compile path (`python/compile/aot.py`) and executes them on the
//! PJRT CPU client — Python is never on this path.
//!
//! Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Artifacts live in `artifacts/` next to `manifest.tsv`, one line per
//! graph: `name \t num_outputs \t spec;spec;…` with spec `f32[2,3]` /
//! `i64[32]`. The manifest is deliberately TSV (no serde_json offline).
//!
//! The whole XLA-touching half of this module sits behind the `aot`
//! Cargo feature (the `xla` binding crate needs network + a local
//! `xla_extension`). Without it, manifest/spec parsing still works, and
//! [`Runtime`]/[`CompiledGraph`] are API-compatible stubs whose entry
//! points return the typed [`TorskError::AotDisabled`] — callers that
//! probe (`Runtime::list`) and skip keep working unmodified.

#[cfg(feature = "aot")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "aot")]
use std::sync::Mutex;
use std::sync::Arc;

use crate::error::{Result, TorskError};
use crate::tensor::{DType, Tensor};

/// Shape+dtype signature of one graph input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (ty, rest) = s
            .split_once('[')
            .ok_or_else(|| TorskError::Artifact(format!("bad spec: {s}")))?;
        let dims = rest
            .strip_suffix(']')
            .ok_or_else(|| TorskError::Artifact(format!("bad spec: {s}")))?;
        let dtype = match ty {
            "f32" => DType::F32,
            "f64" => DType::F64,
            "i64" => DType::I64,
            other => return Err(TorskError::Artifact(format!("unknown dtype {other}"))),
        };
        let shape: Vec<usize> = if dims.is_empty() {
            vec![]
        } else {
            dims.split(',')
                .map(|d| d.trim().parse().map_err(|_| TorskError::Artifact(format!("bad dim in {s}"))))
                .collect::<Result<_>>()?
        };
        Ok(TensorSpec { dtype, shape })
    }

    pub fn to_spec_string(&self) -> String {
        let dims: Vec<String> = self.shape.iter().map(|d| d.to_string()).collect();
        format!(
            "{}[{}]",
            match self.dtype {
                DType::F32 => "f32",
                DType::F64 => "f64",
                DType::I64 => "i64",
            },
            dims.join(",")
        )
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub num_outputs: usize,
    pub inputs: Vec<TensorSpec>,
    pub path: PathBuf,
}

/// Parse `manifest.tsv`.
pub fn parse_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let manifest = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&manifest)
        .map_err(|e| TorskError::Artifact(format!("cannot read {}: {e}", manifest.display())))?;
    let mut out = vec![];
    for (lineno, line) in text.lines().enumerate() {
        // Do NOT trim whole-line: a trailing tab (empty input list) is
        // significant. Only strip a stray carriage return.
        let line = line.trim_end_matches('\r');
        if line.trim().is_empty() || line.trim_start().starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        if parts.len() != 3 {
            return Err(TorskError::Artifact(format!(
                "manifest line {}: expected 3 tab-separated fields",
                lineno + 1
            )));
        }
        let inputs = if parts[2].is_empty() {
            vec![]
        } else {
            parts[2].split(';').map(TensorSpec::parse).collect::<Result<_>>()?
        };
        out.push(ArtifactMeta {
            name: parts[0].to_string(),
            num_outputs: parts[1]
                .parse()
                .map_err(|_| TorskError::Artifact(format!("bad output count on line {}", lineno + 1)))?,
            inputs,
            path: dir.join(format!("{}.hlo.txt", parts[0])),
        });
    }
    Ok(out)
}

/// A compiled XLA graph ready to execute.
#[cfg(feature = "aot")]
pub struct CompiledGraph {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the PJRT CPU client is thread-safe; executions are internally
// synchronized by XLA.
#[cfg(feature = "aot")]
unsafe impl Send for CompiledGraph {}
#[cfg(feature = "aot")]
unsafe impl Sync for CompiledGraph {}

#[cfg(feature = "aot")]
impl CompiledGraph {
    /// Validate inputs against the manifest signature.
    fn check_inputs(&self, inputs: &[Tensor]) {
        crate::torsk_assert!(
            inputs.len() == self.meta.inputs.len(),
            "graph {}: {} inputs given, {} expected",
            self.meta.name,
            inputs.len(),
            self.meta.inputs.len()
        );
        for (i, (t, spec)) in inputs.iter().zip(self.meta.inputs.iter()).enumerate() {
            crate::torsk_assert!(
                t.dtype() == spec.dtype && t.shape() == spec.shape.as_slice(),
                "graph {} input {i}: got {}{:?}, expected {}",
                self.meta.name,
                t.dtype(),
                t.shape(),
                spec.to_spec_string()
            );
        }
    }

    /// Execute with host tensors in/out.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs);
        let literals: Vec<xla::Literal> = inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| TorskError::Xla(e.to_string()))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| TorskError::Xla(e.to_string()))?;
        let elems = tuple.to_tuple().map_err(|e| TorskError::Xla(e.to_string()))?;
        elems.iter().map(literal_to_tensor).collect()
    }

    /// Execute with XLA literals in/out (no torsk-tensor conversion for
    /// state that feeds straight back into the next step — the §6.3
    /// graph-mode fast path; on the CPU PJRT client literals are host
    /// buffers, so this is copy-minimal).
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| TorskError::Xla(e.to_string()))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| TorskError::Xla(e.to_string()))?;
        tuple.to_tuple().map_err(|e| TorskError::Xla(e.to_string()))
    }

    /// Number of graph outputs (manifest).
    pub fn num_outputs(&self) -> usize {
        self.meta.num_outputs
    }
}

/// Convert a (host, contiguous) tensor into an XLA literal.
#[cfg(feature = "aot")]
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let t = t.to_cpu().contiguous();
    let bytes = t.numel() * t.dtype().size();
    // SAFETY: `t` is contiguous (forced above) and alive for this call, so
    // its storage holds exactly `numel * dtype.size()` initialized bytes.
    let data: &[u8] = unsafe { std::slice::from_raw_parts(t.data_ptr().ptr(), bytes) };
    let ty = match t.dtype() {
        DType::F32 => xla::ElementType::F32,
        DType::F64 => xla::ElementType::F64,
        DType::I64 => xla::ElementType::S64,
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, t.shape(), data)
        .map_err(|e| TorskError::Xla(e.to_string()))
}

/// Convert an XLA literal back into a host tensor.
#[cfg(feature = "aot")]
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| TorskError::Xla(e.to_string()))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.primitive_type() {
        xla::PrimitiveType::F32 => {
            let v = l.to_vec::<f32>().map_err(|e| TorskError::Xla(e.to_string()))?;
            Ok(Tensor::from_vec(v, &dims))
        }
        xla::PrimitiveType::S64 => {
            let v = l.to_vec::<i64>().map_err(|e| TorskError::Xla(e.to_string()))?;
            Ok(Tensor::from_vec(v, &dims))
        }
        other => Err(TorskError::Xla(format!("unsupported literal type {other:?}"))),
    }
}

/// The global PJRT runtime: one CPU client + a compile cache keyed by
/// artifact name (one compiled executable per model variant).
#[cfg(feature = "aot")]
pub struct Runtime {
    pub client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    manifest: Mutex<Option<HashMap<String, ArtifactMeta>>>,
    cache: Mutex<HashMap<String, Arc<CompiledGraph>>>,
}

// SAFETY: the PJRT client is thread-safe per the XLA FFI contract, and
// all mutable state (manifest, compile cache) sits behind Mutexes.
#[cfg(feature = "aot")]
unsafe impl Send for Runtime {}
// SAFETY: see Send above — shared access goes through the same Mutexes.
#[cfg(feature = "aot")]
unsafe impl Sync for Runtime {}

#[cfg(feature = "aot")]
impl Runtime {
    /// Create a runtime reading artifacts from `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| TorskError::Xla(e.to_string()))?;
        Ok(Runtime {
            client,
            artifacts_dir: dir.into(),
            manifest: Mutex::new(None),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The process-wide runtime with the default `artifacts/` directory
    /// (override with `TORSK_ARTIFACTS`).
    pub fn global() -> &'static Runtime {
        static RT: once_cell::sync::Lazy<Runtime> = once_cell::sync::Lazy::new(|| {
            let dir = std::env::var("TORSK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Runtime::new(dir).expect("create PJRT CPU client")
        });
        &RT
    }

    fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        let mut guard = self.manifest.lock().unwrap();
        if guard.is_none() {
            let entries = parse_manifest(&self.artifacts_dir)?;
            *guard = Some(entries.into_iter().map(|m| (m.name.clone(), m)).collect());
        }
        guard
            .as_ref()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| TorskError::Artifact(format!("no artifact named `{name}` in manifest")))
    }

    /// Names of all artifacts in the manifest.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut guard = self.manifest.lock().unwrap();
        if guard.is_none() {
            let entries = parse_manifest(&self.artifacts_dir)?;
            *guard = Some(entries.into_iter().map(|m| (m.name.clone(), m)).collect());
        }
        let mut names: Vec<String> = guard.as_ref().unwrap().keys().cloned().collect();
        names.sort();
        Ok(names)
    }

    /// Load (compiling and caching on first use) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Arc<CompiledGraph>> {
        if let Some(g) = self.cache.lock().unwrap().get(name) {
            return Ok(g.clone());
        }
        let meta = self.meta(name)?;
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .map_err(|e| TorskError::Artifact(format!("{}: {e}", meta.path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| TorskError::Xla(e.to_string()))?;
        let graph = Arc::new(CompiledGraph { meta, exe });
        self.cache.lock().unwrap().insert(name.to_string(), graph.clone());
        Ok(graph)
    }

    /// Drop compiled executables (tests).
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
        *self.manifest.lock().unwrap() = None;
    }
}

/// Stub [`CompiledGraph`] for builds without the `aot` feature. It can
/// never be constructed — [`Runtime::load`] always errors — but it keeps
/// downstream code (benches, cross-layer tests) typecheckable so callers
/// probe-and-skip at runtime instead of cfg-gating themselves.
#[cfg(not(feature = "aot"))]
pub struct CompiledGraph {
    pub meta: ArtifactMeta,
    _aot_only: std::convert::Infallible,
}

#[cfg(not(feature = "aot"))]
impl CompiledGraph {
    /// Execute with host tensors in/out (aot builds only).
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self._aot_only {}
    }

    /// Number of graph outputs (manifest).
    pub fn num_outputs(&self) -> usize {
        match self._aot_only {}
    }
}

/// Stub [`Runtime`] for builds without the `aot` feature: construction
/// succeeds (so probing code paths run), but `list`/`load` return the
/// typed [`TorskError::AotDisabled`].
#[cfg(not(feature = "aot"))]
pub struct Runtime {
    artifacts_dir: PathBuf,
}

#[cfg(not(feature = "aot"))]
impl Runtime {
    /// Create a runtime reading artifacts from `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Runtime> {
        Ok(Runtime { artifacts_dir: dir.into() })
    }

    /// The process-wide runtime with the default `artifacts/` directory
    /// (override with `TORSK_ARTIFACTS`).
    pub fn global() -> &'static Runtime {
        static RT: once_cell::sync::Lazy<Runtime> = once_cell::sync::Lazy::new(|| {
            let dir = std::env::var("TORSK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Runtime::new(dir).expect("stub runtime is infallible")
        });
        &RT
    }

    /// Names of all artifacts in the manifest (aot builds only).
    pub fn list(&self) -> Result<Vec<String>> {
        Err(TorskError::aot_disabled(format!(
            "list artifacts in `{}`",
            self.artifacts_dir.display()
        )))
    }

    /// Load an artifact by name (aot builds only).
    pub fn load(&self, name: &str) -> Result<Arc<CompiledGraph>> {
        Err(TorskError::aot_disabled(format!("load artifact `{name}`")))
    }

    /// Drop compiled executables (tests) — nothing cached in the stub.
    pub fn clear_cache(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_roundtrip() {
        let s = TensorSpec::parse("f32[32,3,224,224]").unwrap();
        assert_eq!(s.dtype, DType::F32);
        assert_eq!(s.shape, vec![32, 3, 224, 224]);
        assert_eq!(s.to_spec_string(), "f32[32,3,224,224]");
        let s2 = TensorSpec::parse("i64[8]").unwrap();
        assert_eq!(s2.dtype, DType::I64);
        let s3 = TensorSpec::parse("f32[]").unwrap();
        assert!(s3.shape.is_empty());
    }

    #[test]
    fn spec_parse_rejects_garbage() {
        assert!(TensorSpec::parse("f32").is_err());
        assert!(TensorSpec::parse("q8[2]").is_err());
        assert!(TensorSpec::parse("f32[a,b]").is_err());
    }

    #[test]
    fn manifest_parse() {
        let dir = std::env::temp_dir().join("torsk_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# comment\nmlp_step\t2\tf32[8,4];i64[8]\nnoargs\t1\t\n",
        )
        .unwrap();
        let m = parse_manifest(&dir).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].name, "mlp_step");
        assert_eq!(m[0].num_outputs, 2);
        assert_eq!(m[0].inputs.len(), 2);
        assert_eq!(m[1].inputs.len(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[cfg(feature = "aot")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(vec![1.0f32, -2.0, 3.5, 0.0, 9.0, 7.0], &[2, 3]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        assert_eq!(back.to_vec::<f32>(), t.to_vec::<f32>());
    }

    #[cfg(feature = "aot")]
    #[test]
    fn literal_roundtrip_i64() {
        let t = Tensor::from_vec(vec![5i64, -7, 0], &[3]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back.to_vec::<i64>(), vec![5, -7, 0]);
    }

    #[test]
    fn missing_artifact_is_error() {
        let rt = Runtime::new(std::env::temp_dir().join("definitely_missing_torsk")).unwrap();
        assert!(rt.load("nope").is_err());
    }

    #[cfg(not(feature = "aot"))]
    #[test]
    fn stub_runtime_returns_typed_aot_disabled_error() {
        let rt = Runtime::new("artifacts").unwrap();
        match rt.list() {
            Err(TorskError::AotDisabled { what }) => assert!(what.contains("artifacts"), "{what}"),
            other => panic!("expected AotDisabled, got {other:?}"),
        }
        match rt.load("mlp_step") {
            Err(TorskError::AotDisabled { what }) => assert!(what.contains("mlp_step"), "{what}"),
            other => panic!("expected AotDisabled, got {:?}", other.map(|_| ())),
        }
    }
}
