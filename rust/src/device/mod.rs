//! Simulated accelerator: streams, events, async kernel dispatch (§5.2).
//!
//! The paper's performance story hinges on *separating control flow from
//! data flow*: the host thread resolves Python control flow and merely
//! **queues** kernel launches into a CUDA stream (a hardware FIFO), so the
//! slow interpreted host can run ahead of the device and keep it saturated
//! (Figure 1).
//!
//! We reproduce that architecture with a software device: a [`Stream`] is a
//! worker thread consuming a FIFO of kernel closures. `launch` returns as
//! soon as the closure is enqueued; the host only blocks on an explicit
//! [`Stream::synchronize`], an [`Event`] wait, or a data-dependent read
//! (`Tensor::to_vec` etc.). In-stream ordering is FIFO — the property the
//! caching allocator's one-pool-per-stream design relies on (§5.3).
//!
//! The hardware adaptation rationale is in DESIGN.md §2: the kernels the
//! stream executes are the real native kernels, so timelines measured on
//! this device reflect genuine queue-vs-execute dynamics rather than
//! scripted delays.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::alloc::{DrainAll, StreamId};
use crate::profiler;

/// Where a tensor lives and where its ops execute.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Device {
    /// Host: ops run synchronously on the calling thread.
    Cpu,
    /// Simulated accelerator: ops are queued on the current stream.
    Sim,
}

impl Device {
    /// True if ops on this device are asynchronous w.r.t. the host.
    pub fn is_async(self) -> bool {
        matches!(self, Device::Sim)
    }
}

impl std::fmt::Display for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Device::Cpu => write!(f, "cpu"),
            Device::Sim => write!(f, "sim"),
        }
    }
}

type Job = Box<dyn FnOnce() + Send>;

struct QueueState {
    jobs: VecDeque<(String, Job)>,
    /// Jobs enqueued but not yet completed (includes the one executing).
    outstanding: usize,
    shutdown: bool,
}

struct StreamShared {
    state: Mutex<QueueState>,
    /// Signalled when a job is pushed or shutdown is requested.
    work_cv: Condvar,
    /// Signalled when `outstanding` reaches zero.
    idle_cv: Condvar,
}

/// A device work queue with FIFO execution semantics (a CUDA stream).
pub struct Stream {
    pub id: StreamId,
    shared: Arc<StreamShared>,
    worker: Mutex<Option<JoinHandle<()>>>,
    launched: AtomicU64,
}

impl Stream {
    fn spawn(id: StreamId) -> Arc<Stream> {
        let shared = Arc::new(StreamShared {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), outstanding: 0, shutdown: false }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let worker_shared = shared.clone();
        let handle = std::thread::Builder::new()
            .name(format!("torsk-stream-{}", id.0))
            .spawn(move || Self::worker_loop(id, worker_shared))
            .expect("spawn stream worker");
        Arc::new(Stream {
            id,
            shared,
            worker: Mutex::new(Some(handle)),
            launched: AtomicU64::new(0),
        })
    }

    fn worker_loop(id: StreamId, shared: Arc<StreamShared>) {
        loop {
            let (name, job) = {
                let mut st = shared.state.lock().unwrap();
                loop {
                    if let Some(j) = st.jobs.pop_front() {
                        break j;
                    }
                    if st.shutdown {
                        return;
                    }
                    st = shared.work_cv.wait(st).unwrap();
                }
            };
            // Execute outside the lock; this is the "device" doing work.
            let span = profiler::begin(profiler::Track::Stream(id.0), &name);
            job();
            profiler::end(span);
            let mut st = shared.state.lock().unwrap();
            st.outstanding -= 1;
            if st.outstanding == 0 {
                shared.idle_cv.notify_all();
            }
        }
    }

    /// Queue a kernel for execution. Returns immediately — this is the
    /// `<<<...>>>`-style async launch of §5.2. `name` labels the op in
    /// profiler timelines.
    pub fn launch(&self, name: &str, job: impl FnOnce() + Send + 'static) {
        let span = profiler::begin(profiler::Track::Host, &format!("launch {name}"));
        {
            let mut st = self.shared.state.lock().unwrap();
            assert!(!st.shutdown, "launch on shut-down stream");
            st.outstanding += 1;
            st.jobs.push_back((name.to_string(), Box::new(job)));
        }
        self.shared.work_cv.notify_one();
        self.launched.fetch_add(1, Ordering::Relaxed);
        profiler::end(span);
    }

    /// Block the host until every queued kernel has finished
    /// (`cudaStreamSynchronize`).
    pub fn synchronize(&self) {
        let span = profiler::begin(profiler::Track::Host, "synchronize");
        let mut st = self.shared.state.lock().unwrap();
        while st.outstanding > 0 {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
        drop(st);
        profiler::end(span);
    }

    /// Number of kernels launched on this stream since creation.
    pub fn launch_count(&self) -> u64 {
        self.launched.load(Ordering::Relaxed)
    }

    /// Jobs queued or running right now (0 = idle).
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().outstanding
    }

    fn shutdown(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Stream {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A synchronization marker (CUDA event): record on one stream, wait on
/// another (or on the host). Used by the data loader and multi-stream
/// utilities, which "carefully insert additional synchronization" (§5.3).
#[derive(Clone)]
pub struct Event {
    inner: Arc<(Mutex<bool>, Condvar)>,
}

impl Event {
    pub fn new() -> Event {
        Event { inner: Arc::new((Mutex::new(false), Condvar::new())) }
    }

    /// Enqueue a marker on `stream`; the event fires when the device
    /// reaches it.
    pub fn record(&self, stream: &Stream) {
        let inner = self.inner.clone();
        stream.launch("event_record", move || {
            let (lock, cv) = &*inner;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        });
    }

    /// Fire the event immediately from the host.
    pub fn record_host(&self) {
        let (lock, cv) = &*self.inner;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    /// Make `stream` wait (on the device, without blocking the host) until
    /// the event fires.
    pub fn wait_stream(&self, stream: &Stream) {
        let inner = self.inner.clone();
        stream.launch("event_wait", move || {
            let (lock, cv) = &*inner;
            let mut fired = lock.lock().unwrap();
            while !*fired {
                fired = cv.wait(fired).unwrap();
            }
        });
    }

    /// Block the host until the event fires.
    pub fn wait_host(&self) {
        let (lock, cv) = &*self.inner;
        let mut fired = lock.lock().unwrap();
        while !*fired {
            fired = cv.wait(fired).unwrap();
        }
    }

    /// Non-blocking check.
    pub fn query(&self) -> bool {
        *self.inner.0.lock().unwrap()
    }
}

impl Default for Event {
    fn default() -> Self {
        Self::new()
    }
}

/// The set of live streams on the simulated device. Implements [`DrainAll`]
/// so the simulated driver's `cudaFree` can synchronize the whole device.
pub struct Streams {
    streams: Mutex<Vec<Arc<Stream>>>,
}

impl Streams {
    fn new() -> Streams {
        Streams { streams: Mutex::new(Vec::new()) }
    }

    /// Get (creating on first use) the stream with the given id.
    pub fn get(&self, id: StreamId) -> Arc<Stream> {
        let mut streams = self.streams.lock().unwrap();
        if let Some(s) = streams.iter().find(|s| s.id == id) {
            return s.clone();
        }
        let s = Stream::spawn(id);
        streams.push(s.clone());
        s
    }

    /// The default stream (id 0) — "in practice PyTorch almost never uses
    /// multiple streams" (§5.3).
    pub fn default_stream(&self) -> Arc<Stream> {
        self.get(StreamId::DEFAULT)
    }

    /// Synchronize every stream (`cudaDeviceSynchronize`).
    pub fn synchronize_all(&self) {
        let streams: Vec<Arc<Stream>> = self.streams.lock().unwrap().clone();
        for s in streams {
            s.synchronize();
        }
    }
}

impl DrainAll for Streams {
    fn drain_all(&self) {
        self.synchronize_all();
    }
}

static STREAMS: once_cell::sync::Lazy<Arc<Streams>> =
    once_cell::sync::Lazy::new(|| Arc::new(Streams::new()));

/// Global stream registry for the (single) simulated device.
pub fn streams() -> Arc<Streams> {
    STREAMS.clone()
}

thread_local! {
    static CURRENT_STREAM: std::cell::Cell<StreamId> = const { std::cell::Cell::new(StreamId::DEFAULT) };
    static DEFAULT_DEVICE: std::cell::Cell<Device> = const { std::cell::Cell::new(Device::Cpu) };
}

/// The device new tensors are created on (like `torch.set_default_device`).
pub fn default_device() -> Device {
    DEFAULT_DEVICE.with(|c| c.get())
}

/// Set this thread's default tensor device.
pub fn set_default_device(d: Device) {
    DEFAULT_DEVICE.with(|c| c.set(d));
}

/// Run `f` with a scoped default device (models built inside are placed
/// on `d`).
pub fn with_default_device<R>(d: Device, f: impl FnOnce() -> R) -> R {
    let prev = DEFAULT_DEVICE.with(|c| c.replace(d));
    let out = f();
    DEFAULT_DEVICE.with(|c| c.set(prev));
    out
}

/// The stream new Sim-device work is queued on from this thread.
pub fn current_stream() -> Arc<Stream> {
    let id = CURRENT_STREAM.with(|c| c.get());
    streams().get(id)
}

/// Current stream id without materializing the stream.
pub fn current_stream_id() -> StreamId {
    CURRENT_STREAM.with(|c| c.get())
}

/// Run `f` with a different current stream (RAII-style scoping).
pub fn with_stream<R>(id: StreamId, f: impl FnOnce() -> R) -> R {
    let prev = CURRENT_STREAM.with(|c| c.replace(id));
    let out = f();
    CURRENT_STREAM.with(|c| c.set(prev));
    out
}

/// Synchronize the whole simulated device.
pub fn synchronize() {
    streams().synchronize_all();
}

static ASYNC_ENABLED: AtomicBool = AtomicBool::new(true);

/// Globally disable async dispatch: launches run inline on the host thread.
/// This is the "NaiveEager" (Chainer-like) mode used as a Table 1 baseline
/// and is also handy for deterministic debugging — mirroring
/// `CUDA_LAUNCH_BLOCKING=1`.
pub fn set_async_enabled(enabled: bool) {
    ASYNC_ENABLED.store(enabled, Ordering::SeqCst);
}

/// Whether async dispatch is enabled (see [`set_async_enabled`]).
pub fn async_enabled() -> bool {
    ASYNC_ENABLED.load(Ordering::SeqCst)
}

/// Dispatch a kernel for a tensor op on `device`: inline for CPU (or when
/// launch-blocking), queued on the current stream for Sim.
pub fn dispatch(device: Device, name: &str, job: impl FnOnce() + Send + 'static) {
    match device {
        Device::Cpu => {
            let span = profiler::begin(profiler::Track::Host, name);
            job();
            profiler::end(span);
        }
        Device::Sim => {
            if async_enabled() {
                current_stream().launch(name, job);
            } else {
                let stream_id = current_stream_id();
                let span = profiler::begin(profiler::Track::Stream(stream_id.0), name);
                job();
                profiler::end(span);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn launch_returns_before_execution_completes() {
        let s = Stream::spawn(StreamId(100));
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = flag.clone();
        s.launch("slow", move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            f2.store(true, Ordering::SeqCst);
        });
        // Host got control back before the job finished.
        assert!(!flag.load(Ordering::SeqCst));
        s.synchronize();
        assert!(flag.load(Ordering::SeqCst));
    }

    #[test]
    fn fifo_ordering_within_stream() {
        let s = Stream::spawn(StreamId(101));
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..64 {
            let o = order.clone();
            s.launch("step", move || o.lock().unwrap().push(i));
        }
        s.synchronize();
        let got = order.lock().unwrap().clone();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn synchronize_on_idle_stream_is_immediate() {
        let s = Stream::spawn(StreamId(102));
        s.synchronize();
        assert_eq!(s.queue_depth(), 0);
    }

    #[test]
    fn event_orders_two_streams() {
        let a = Stream::spawn(StreamId(103));
        let b = Stream::spawn(StreamId(104));
        let log = Arc::new(Mutex::new(Vec::new()));
        let ev = Event::new();

        let l1 = log.clone();
        a.launch("producer", move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            l1.lock().unwrap().push("produced");
        });
        ev.record(&a);
        ev.wait_stream(&b);
        let l2 = log.clone();
        b.launch("consumer", move || l2.lock().unwrap().push("consumed"));

        a.synchronize();
        b.synchronize();
        assert_eq!(*log.lock().unwrap(), vec!["produced", "consumed"]);
    }

    #[test]
    fn event_query_and_host_wait() {
        let s = Stream::spawn(StreamId(105));
        let ev = Event::new();
        assert!(!ev.query());
        s.launch("work", || std::thread::sleep(std::time::Duration::from_millis(20)));
        ev.record(&s);
        ev.wait_host();
        assert!(ev.query());
    }

    #[test]
    fn streams_registry_reuses_instances() {
        let st = streams();
        let a = st.get(StreamId(7));
        let b = st.get(StreamId(7));
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn with_stream_scopes_current() {
        assert_eq!(current_stream_id(), StreamId::DEFAULT);
        with_stream(StreamId(3), || {
            assert_eq!(current_stream_id(), StreamId(3));
        });
        assert_eq!(current_stream_id(), StreamId::DEFAULT);
    }

    #[test]
    fn dispatch_cpu_runs_inline() {
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        dispatch(Device::Cpu, "inline", move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn host_runs_ahead_queue_depth_grows() {
        // The Figure 1 phenomenon: queueing is much faster than executing,
        // so the FIFO depth grows while the device churns.
        let s = Stream::spawn(StreamId(106));
        for _ in 0..32 {
            s.launch("ms_kernel", || std::thread::sleep(std::time::Duration::from_micros(500)));
        }
        assert!(s.queue_depth() > 8, "host should outpace device");
        s.synchronize();
        assert_eq!(s.queue_depth(), 0);
    }
}
