//! # torsk — an imperative-style, high-performance deep learning library
//!
//! A Rust reproduction of **"PyTorch: An Imperative Style, High-Performance
//! Deep Learning Library"** (Paszke et al., NeurIPS 2019) as a three-layer
//! Rust + JAX + Pallas stack. `ARCHITECTURE.md` at the repo root is the
//! guided tour of the subsystems (with a worked trace of one op from API
//! call to backward); see `DESIGN.md` for the full system map and
//! `EXPERIMENTS.md` for the paper-vs-measured results.
//!
//! The crate provides:
//! - [`tensor`] — strided, reference-counted tensors with mutation
//!   versioning (§5.5, §4.3); f32/f64 compute plus i64 indices;
//! - [`autograd`] — define-by-run reverse-mode AD with a multithreaded
//!   backward engine (§4.3, §5.1);
//! - [`dispatch`] — the ATen-style central operator registry: every op is
//!   declared once (schema + per-`DispatchKey` kernels) and every call
//!   funnels through `dispatch::call`, which validates, routes to the
//!   backend key, promotes dtypes, profiles, and records autograd (§5.1);
//! - [`ops`] — the stable eager API: thin shims over the dispatcher, plus
//!   `Tensor` methods and operator overloads (§5.2);
//! - [`alloc`] — the caching device allocator and its baselines (§5.3);
//! - [`device`] — streams, events, and the simulated accelerator (§5.2);
//! - [`nn`], [`optim`] — the "just Python programs" model and optimizer
//!   APIs, in Rust (§4.1);
//! - [`data`] — the parallel prefetching data pipeline: samplers,
//!   collation through the caching allocator, and a worker-thread
//!   `DataLoader` whose batch stream is bitwise worker-count-invariant
//!   (§4.2);
//! - [`multiproc`] — shared-memory tensor transport + Hogwild (§5.4);
//! - [`serialize`] — versioned, checksummed training checkpoints with
//!   atomic writes and bitwise resume (model + optimizer + RNG + loader
//!   coordinates);
//! - [`serve`] — inference serving: concurrent requests coalesced into
//!   dynamic batches (size-or-deadline), bucket-padded so the capture
//!   guard cache replays compiled graphs, with live lock-free latency
//!   telemetry (`serve_stats()`);
//! - [`runtime`] / [`graph`] — AOT-compiled XLA graph execution via PJRT,
//!   the static-graph baseline of §6.3. The XLA/PJRT half lives behind
//!   the `aot` Cargo feature (off by default — the `xla` git dependency
//!   needs network + a local `xla_extension`); default builds get
//!   API-compatible stubs returning [`TorskError::AotDisabled`];
//! - [`models`] — the six Table 1 benchmark models;
//! - [`profiler`] — the Figure 1/2 instrumentation;
//! - [`adoption`] — the Figure 3 mention-counting pipeline.
//!
//! ## Quickstart
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the xla_extension
//! # // rpath, so they cannot load libstdc++ at runtime. The same code is
//! # // exercised (and executed) in examples/quickstart.rs and the tests.
//! use torsk::prelude::*;
//!
//! torsk::rng::manual_seed(0);
//! let x = Tensor::randn(&[8, 4]);
//! let w = Tensor::randn(&[3, 4]).requires_grad(true);
//! let b = Tensor::zeros(&[3]).requires_grad(true);
//! let y = ops::linear(&x, &w, Some(&b)).relu();
//! let loss = y.mean();
//! loss.backward();
//! assert_eq!(w.grad().unwrap().shape(), &[3, 4]);
//! ```

// Every unsafe operation inside an unsafe fn must be wrapped in its own
// `unsafe {}` block with a SAFETY justification (enforced by pallas-audit).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod adoption;
pub mod alloc;
#[cfg(feature = "debug-checks")]
pub mod debug_checks;
pub mod autograd;
pub mod cli;
pub mod ctx;
pub mod data;
pub mod device;
pub mod dispatch;
pub mod error;
pub mod graph;
pub mod kernels;
pub mod models;
pub mod multiproc;
pub mod nn;
pub mod ops;
pub mod optim;
pub mod profiler;
pub mod rng;
pub mod runtime;
pub mod serialize;
pub mod serve;
pub mod tensor;
pub mod testing;

pub use error::{Result, TorskError};
pub use tensor::{DType, Tensor};

/// Common imports for user programs.
pub mod prelude {
    pub use crate::autograd::{self, no_grad};
    pub use crate::device::Device;
    pub use crate::nn::{self, Module};
    pub use crate::ops;
    pub use crate::optim::{self, Optimizer};
    pub use crate::tensor::{assert_close, DType, Tensor};
}
