//! Figure 3: framework-adoption analysis.
//!
//! The paper counts, for each month since PyTorch's release, the share of
//! arXiv e-prints mentioning deep-learning frameworks that mention
//! PyTorch — "tools mentioned multiple times in a given paper only once,
//! and … case insensitive". We cannot query arXiv offline (DESIGN.md §2
//! substitution), so this module implements (a) the *counting pipeline*
//! exactly as described, and (b) a synthetic corpus generator with a
//! logistic adoption model whose parameters mimic the paper's observed
//! trajectory (PyTorch rising from 0% in Jan 2017 toward ~50% by mid-2019).

use crate::rng::Rng;

/// The frameworks the paper searches for.
pub const FRAMEWORKS: [&str; 8] =
    ["caffe", "chainer", "cntk", "keras", "mxnet", "pytorch", "tensorflow", "theano"];

/// One synthetic paper: an id and its abstract text.
#[derive(Clone, Debug)]
pub struct Paper {
    pub month: usize,
    pub text: String,
}

/// Case-insensitive, dedup-per-paper mention counting — the Figure 3
/// methodology.
pub fn count_mentions(papers: &[Paper], months: usize) -> Vec<MonthCounts> {
    let mut out = vec![MonthCounts::default(); months];
    for p in papers {
        if p.month >= months {
            continue;
        }
        let lower = p.text.to_lowercase();
        let mentioned: Vec<&str> =
            FRAMEWORKS.iter().copied().filter(|f| lower.contains(f)).collect();
        if mentioned.is_empty() {
            continue;
        }
        let mc = &mut out[p.month];
        mc.papers_mentioning_any += 1;
        for f in mentioned {
            let idx = FRAMEWORKS.iter().position(|&x| x == f).unwrap();
            mc.by_framework[idx] += 1;
        }
    }
    out
}

/// Per-month counts.
#[derive(Clone, Debug, Default)]
pub struct MonthCounts {
    /// Papers mentioning at least one framework.
    pub papers_mentioning_any: usize,
    /// Papers mentioning each framework (dedup within a paper).
    pub by_framework: [usize; 8],
}

impl MonthCounts {
    /// Percentage of framework-mentioning papers that mention `name`.
    pub fn percent(&self, name: &str) -> f64 {
        let idx = FRAMEWORKS.iter().position(|&x| x == name).expect("known framework");
        if self.papers_mentioning_any == 0 {
            0.0
        } else {
            100.0 * self.by_framework[idx] as f64 / self.papers_mentioning_any as f64
        }
    }
}

/// Parameters of the synthetic adoption model.
#[derive(Clone, Copy, Debug)]
pub struct AdoptionModel {
    /// Months simulated (paper: Jan 2017 – mid 2019 ≈ 30).
    pub months: usize,
    /// Framework-mentioning papers per month.
    pub papers_per_month: usize,
    /// Logistic ceiling for PyTorch share (paper trajectory ≈ 0.5).
    pub ceiling: f64,
    /// Logistic growth rate per month.
    pub rate: f64,
    /// Logistic midpoint month.
    pub midpoint: f64,
}

impl Default for AdoptionModel {
    fn default() -> Self {
        AdoptionModel { months: 30, papers_per_month: 400, ceiling: 0.55, rate: 0.25, midpoint: 14.0 }
    }
}

impl AdoptionModel {
    /// Ground-truth PyTorch mention probability at `month`.
    pub fn pytorch_prob(&self, month: usize) -> f64 {
        self.ceiling / (1.0 + (-self.rate * (month as f64 - self.midpoint)).exp())
    }

    /// Generate the corpus: each paper mentions 1–3 frameworks, PyTorch
    /// with the logistic probability, the rest drawn from a slowly
    /// decaying incumbent mix (TensorFlow/Keras heavy, like 2017 arXiv).
    pub fn generate(&self, seed: u64) -> Vec<Paper> {
        let mut r = Rng::new(seed);
        let mut papers = Vec::with_capacity(self.months * self.papers_per_month);
        let fillers = ["We train a deep network", "Our method uses", "Experiments implemented in", "Baselines run on"];
        for month in 0..self.months {
            let p_pt = self.pytorch_prob(month);
            for _ in 0..self.papers_per_month {
                let mut text = String::new();
                text.push_str(fillers[r.below(fillers.len() as u64) as usize]);
                // Incumbents: always at least one to make the paper count.
                let incumbent = match r.below(100) {
                    0..=44 => "TensorFlow",
                    45..=69 => "Keras",
                    70..=79 => "Caffe",
                    80..=87 => "MXNet",
                    88..=93 => "Theano",
                    94..=97 => "CNTK",
                    _ => "Chainer",
                };
                text.push(' ');
                text.push_str(incumbent);
                if (r.uniform() as f64) < p_pt {
                    // Vary spelling/case — the pipeline must be
                    // case-insensitive, per the paper.
                    let spellings = ["PyTorch", "pytorch", "Pytorch", "PYTORCH"];
                    text.push_str(" and ");
                    text.push_str(spellings[r.below(4) as usize]);
                    // Mention it twice sometimes: dedup must count once.
                    if r.bernoulli(0.3) {
                        text.push_str(". PyTorch was fast");
                    }
                }
                papers.push(Paper { month, text });
            }
        }
        papers
    }
}

/// The Figure 3 series: PyTorch share per month (percent).
pub fn pytorch_share_series(counts: &[MonthCounts]) -> Vec<f64> {
    counts.iter().map(|m| m.percent("pytorch")).collect()
}

/// Render the series as an ASCII chart (the Figure 3 plot).
pub fn ascii_chart(series: &[f64], height: usize) -> String {
    let maxv = series.iter().cloned().fold(1.0f64, f64::max);
    let mut out = String::new();
    for row in (0..height).rev() {
        let threshold = maxv * (row as f64 + 0.5) / height as f64;
        out.push_str(&format!("{:5.1}% |", maxv * (row as f64 + 1.0) / height as f64));
        for &v in series {
            out.push(if v >= threshold { '#' } else { ' ' });
        }
        out.push('\n');
    }
    out.push_str(&format!("       +{}\n", "-".repeat(series.len())));
    out.push_str("        Jan'17 ->  months  -> mid'19\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_is_case_insensitive() {
        let papers = vec![
            Paper { month: 0, text: "We use PYTORCH and TensorFlow".into() },
            Paper { month: 0, text: "we use pytorch".into() },
            Paper { month: 0, text: "keras only".into() },
        ];
        let counts = count_mentions(&papers, 1);
        assert_eq!(counts[0].papers_mentioning_any, 3);
        assert_eq!(counts[0].percent("pytorch"), 100.0 * 2.0 / 3.0);
    }

    #[test]
    fn multiple_mentions_count_once() {
        let papers = vec![Paper { month: 0, text: "PyTorch pytorch PyTorch!".into() }];
        let counts = count_mentions(&papers, 1);
        assert_eq!(counts[0].by_framework[5], 1);
    }

    #[test]
    fn papers_without_frameworks_are_excluded() {
        let papers = vec![Paper { month: 0, text: "a paper about biology".into() }];
        let counts = count_mentions(&papers, 1);
        assert_eq!(counts[0].papers_mentioning_any, 0);
        assert_eq!(counts[0].percent("pytorch"), 0.0);
    }

    #[test]
    fn synthetic_series_rises_monotonically_in_trend() {
        let model = AdoptionModel::default();
        let papers = model.generate(7);
        let counts = count_mentions(&papers, model.months);
        let series = pytorch_share_series(&counts);
        // Start low, end near ceiling (the Figure 3 shape).
        assert!(series[0] < 10.0, "start {}", series[0]);
        assert!(series[model.months - 1] > 40.0, "end {}", series[model.months - 1]);
        // Trend: late average well above early average.
        let early: f64 = series[..6].iter().sum::<f64>() / 6.0;
        let late: f64 = series[model.months - 6..].iter().sum::<f64>() / 6.0;
        assert!(late > early + 25.0, "early {early} late {late}");
    }

    #[test]
    fn measured_share_tracks_ground_truth() {
        let model = AdoptionModel::default();
        let papers = model.generate(11);
        let counts = count_mentions(&papers, model.months);
        for month in [0usize, 10, 20, 29] {
            let measured = counts[month].percent("pytorch") / 100.0;
            let truth = model.pytorch_prob(month);
            assert!((measured - truth).abs() < 0.08, "month {month}: {measured} vs {truth}");
        }
    }

    #[test]
    fn chart_renders() {
        let chart = ascii_chart(&[1.0, 5.0, 20.0, 45.0], 5);
        assert!(chart.contains('#'));
        assert!(chart.lines().count() >= 6);
    }
}
