//! Graph capture vs eager parity: replaying a captured (fused, DCE'd,
//! buffer-planned) graph must match the plain eager run **bit for bit**
//! — forward and backward — at `PALLAS_NUM_THREADS` = 1, 2 and 8, in
//! both the vectorized and forced-scalar SIMD modes.
//!
//! The replay path re-dispatches plain steps through the same kernels
//! and runs fused regions through the same fixed-chunk tape drivers the
//! hand-registered `fused:*` ops use, so equality here is structural,
//! not a tolerance. The whole file also runs under `--features
//! debug-checks` in CI, which validates every donated/dropped buffer
//! the planner produces.

use torsk::dispatch::{self, GraphCapture};
use torsk::kernels::set_num_threads;
use torsk::kernels::simd::set_force_scalar;
use torsk::ops;
use torsk::testing::{for_all, gen_vec};
use torsk::Tensor;

const THREADS: [usize; 3] = [1, 2, 8];

fn bits(v: Vec<f32>) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Forward bits + per-leaf gradient bits of `f(leaves)` run plain eager.
fn eager_fwd_bwd(inputs: &[Tensor], f: impl Fn(&[Tensor]) -> Tensor) -> (Vec<u32>, Vec<Vec<u32>>) {
    let leaves: Vec<Tensor> = inputs.iter().map(|t| t.detach().requires_grad(true)).collect();
    let out = f(&leaves);
    ops::sum(&out).backward();
    let grads = leaves
        .iter()
        .map(|l| bits(l.grad().expect("grad flows").to_vec::<f32>()))
        .collect();
    (bits(out.to_vec::<f32>()), grads)
}

/// Same computation through a capture session: the first `run` traces
/// (discarded, no backward), the second replays the optimized plan; the
/// replay's forward and backward bits are returned. Panics if nothing
/// was actually captured — a silent eager fallback would make the
/// parity assertions vacuous.
fn captured_fwd_bwd(
    inputs: &[Tensor],
    f: impl Fn(&[&Tensor]) -> Tensor,
) -> (Vec<u32>, Vec<Vec<u32>>) {
    let leaves: Vec<Tensor> = inputs.iter().map(|t| t.detach().requires_grad(true)).collect();
    let refs: Vec<&Tensor> = leaves.iter().collect();
    let sess = GraphCapture::new("test:capture_parity");
    let _trace = sess.run(&refs, &f);
    assert!(sess.cached_graphs() >= 1, "capture refused; parity test would be vacuous");
    let out = sess.run(&refs, &f);
    ops::sum(&out).backward();
    let grads = leaves
        .iter()
        .map(|l| bits(l.grad().expect("grad flows").to_vec::<f32>()))
        .collect();
    (bits(out.to_vec::<f32>()), grads)
}

/// Assert captured == eager across the full thread × SIMD matrix.
fn parity_sweep(
    inputs: &[Tensor],
    eager: impl Fn(&[Tensor]) -> Tensor,
    captured: impl Fn(&[&Tensor]) -> Tensor,
) -> bool {
    let mut ok = true;
    for &th in THREADS.iter() {
        for &scalar in &[false, true] {
            set_num_threads(th);
            set_force_scalar(scalar);
            let e = eager_fwd_bwd(inputs, &eager);
            let c = captured_fwd_bwd(inputs, &captured);
            ok &= e == c;
        }
    }
    set_force_scalar(false);
    set_num_threads(0);
    ok
}

// ---------------------------------------------------------------------
// Tentpole: MLP and conv blocks, full thread × SIMD matrix
// ---------------------------------------------------------------------

#[test]
fn mlp_block_capture_replay_bitwise_across_threads_and_simd() {
    for_all(
        "captured MLP block == eager, fwd+bwd",
        3,
        |r| {
            let b = 1 + r.below(6) as usize;
            let (din, dh, dout) = (5, 7, 3);
            (
                b,
                gen_vec(r, b * din, -2.0, 2.0),
                gen_vec(r, dh * din, -1.0, 1.0),
                gen_vec(r, dh, -0.5, 0.5),
                gen_vec(r, dout * dh, -1.0, 1.0),
                gen_vec(r, dout, -0.5, 0.5),
                gen_vec(r, b * dout, -1.0, 1.0),
            )
        },
        |(b, xv, w1v, b1v, w2v, b2v, tv)| {
            let (din, dh, dout) = (5, 7, 3);
            let inputs = [
                Tensor::from_vec(xv.clone(), &[*b, din]),
                Tensor::from_vec(w1v.clone(), &[dh, din]),
                Tensor::from_vec(b1v.clone(), &[dh]),
                Tensor::from_vec(w2v.clone(), &[dout, dh]),
                Tensor::from_vec(b2v.clone(), &[dout]),
                Tensor::from_vec(tv.clone(), &[*b, dout]),
            ];
            let mlp_loss = |x: &Tensor, w1: &Tensor, b1: &Tensor, w2: &Tensor, b2: &Tensor, t: &Tensor| {
                let h = ops::relu(&ops::linear(x, w1, Some(b1)));
                let y = ops::linear(&h, w2, Some(b2));
                ops::mse_loss(&y, t)
            };
            parity_sweep(
                &inputs,
                |l| mlp_loss(&l[0], &l[1], &l[2], &l[3], &l[4], &l[5]),
                |l| mlp_loss(l[0], l[1], l[2], l[3], l[4], l[5]),
            )
        },
    );
}

#[test]
fn conv_block_capture_replay_bitwise_across_threads_and_simd() {
    for_all(
        "captured conv block == eager, fwd+bwd",
        3,
        |r| {
            let n = 1 + r.below(2) as usize;
            let hw = 4 + r.below(5) as usize;
            (
                n,
                hw,
                gen_vec(r, n * 4 * hw * hw, -2.0, 2.0),
                gen_vec(r, 4 * 4 * 9, -0.5, 0.5),
                gen_vec(r, 4, -0.2, 0.2),
            )
        },
        |(n, hw, xv, wv, bv)| {
            let inputs = [
                Tensor::from_vec(xv.clone(), &[*n, 4, *hw, *hw]),
                Tensor::from_vec(wv.clone(), &[4, 4, 3, 3]),
                Tensor::from_vec(bv.clone(), &[4]),
            ];
            // conv → relu → residual add: the relu/add pair auto-fuses
            // into one region (one autograd node), conv stays a plain
            // replayed step.
            let block = |x: &Tensor, w: &Tensor, b: &Tensor| {
                let y = ops::conv2d(x, w, Some(b), 1, 1, 1);
                ops::add(&ops::relu(&y), x)
            };
            parity_sweep(
                &inputs,
                |l| block(&l[0], &l[1], &l[2]),
                |l| block(l[0], l[1], l[2]),
            )
        },
    );
}

// ---------------------------------------------------------------------
// Guard behavior: shape change recaptures, both graphs replay bitwise
// ---------------------------------------------------------------------

#[test]
fn guard_recaptures_on_shape_change_both_replay_bitwise() {
    let sess = GraphCapture::new("test:guard");
    let f = |ins: &[&Tensor]| ops::mul(&ops::relu(&ops::add(ins[0], ins[0])), ins[0]);
    for &n in &[64usize, 96] {
        let xv = gen_vec(&mut torsk::rng::Rng::new(7 + n as u64), n, -2.0, 2.0);
        let x = Tensor::from_vec(xv, &[n]);
        let eager = bits(f(&[&x]).to_vec::<f32>());
        let traced = bits(sess.run(&[&x], f).to_vec::<f32>());
        let replayed = bits(sess.run(&[&x], f).to_vec::<f32>());
        assert_eq!(eager, traced, "trace run diverged at n={n}");
        assert_eq!(eager, replayed, "replay diverged at n={n}");
    }
    assert_eq!(sess.cached_graphs(), 2, "each shape compiles its own graph");
}

// ---------------------------------------------------------------------
// Satellite: auto-fused composite wrappers vs hand-registered tapes
// ---------------------------------------------------------------------

#[test]
fn auto_fused_mse_matches_hand_registered_fused_mse() {
    for_all(
        "captured mse_loss == fused:mse, fwd+bwd",
        4,
        |r| {
            let n = 1 + r.below(70_000) as usize;
            (gen_vec(r, n, -2.0, 2.0), gen_vec(r, n, -2.0, 2.0))
        },
        |(pv, tv)| {
            let inputs = [
                Tensor::from_vec(pv.clone(), &[pv.len()]),
                Tensor::from_vec(tv.clone(), &[tv.len()]),
            ];
            // Eager side dispatches the hand-registered fused:mse tape;
            // the captured side traces the primitive chain and re-fuses
            // it automatically. Both must agree bitwise.
            parity_sweep(
                &inputs,
                |l| ops::mse_loss(&l[0], &l[1]),
                |l| ops::mse_loss(l[0], l[1]),
            )
        },
    );
}

#[test]
fn auto_fused_bce_matches_hand_registered_fused_bce() {
    for_all(
        "captured bce_loss == fused:bce, fwd+bwd",
        4,
        |r| {
            let n = 1 + r.below(70_000) as usize;
            (gen_vec(r, n, 0.01, 0.99), gen_vec(r, n, 0.0, 1.0))
        },
        |(pv, tv)| {
            let inputs = [
                Tensor::from_vec(pv.clone(), &[pv.len()]),
                Tensor::from_vec(tv.clone(), &[tv.len()]),
            ];
            parity_sweep(
                &inputs,
                |l| ops::bce_loss(&l[0], &l[1]),
                |l| ops::bce_loss(l[0], l[1]),
            )
        },
    );
}

#[test]
fn auto_fused_layer_norm_matches_hand_registered_ln_tail() {
    for_all(
        "captured layer_norm == fused:ln_tail path, fwd+bwd",
        4,
        |r| {
            let rows = 1 + r.below(24) as usize;
            let d = 1 + r.below(192) as usize;
            (
                rows,
                d,
                gen_vec(r, rows * d, -2.0, 2.0),
                gen_vec(r, d, 0.5, 1.5),
                gen_vec(r, d, -0.5, 0.5),
            )
        },
        |(rows, d, xv, gv, bv)| {
            let inputs = [
                Tensor::from_vec(xv.clone(), &[*rows, *d]),
                Tensor::from_vec(gv.clone(), &[*d]),
                Tensor::from_vec(bv.clone(), &[*d]),
            ];
            parity_sweep(
                &inputs,
                |l| ops::layer_norm(&l[0], &l[1], &l[2], 1e-5),
                |l| ops::layer_norm(l[0], l[1], l[2], 1e-5),
            )
        },
    );
}

// ---------------------------------------------------------------------
// Optimizer passes: DCE'd + buffer-planned graphs replay clean
// (run under --features debug-checks in CI to validate every donation)
// ---------------------------------------------------------------------

#[test]
fn dce_and_buffer_planning_replay_matches_eager() {
    let before = dispatch::capture_stats();
    let sess = GraphCapture::new("test:dce_plan");
    // `dead` is never used by the result: DCE must drop it. The second
    // matmul consumes the first's dying output, so the planner donates
    // that buffer; relu + mul_scalar re-fuse into one region.
    let f = |ins: &[&Tensor]| {
        let _dead = ops::exp(ins[0]);
        let y = ops::matmul(ins[0], ins[0]);
        let z = ops::matmul(&y, ins[0]);
        ops::mul_scalar(&ops::relu(&z), 0.5)
    };
    let x = Tensor::from_vec(gen_vec(&mut torsk::rng::Rng::new(23), 36, -1.5, 1.5), &[6, 6]);
    let eager = bits(f(&[&x]).to_vec::<f32>());
    let _ = sess.run(&[&x], f);
    assert_eq!(sess.cached_graphs(), 1);
    let replayed = bits(sess.run(&[&x], f).to_vec::<f32>());
    assert_eq!(eager, replayed, "optimized replay diverged from eager");
    let after = dispatch::capture_stats();
    assert!(after.graphs_captured > before.graphs_captured);
    assert!(after.buffers_planned > before.buffers_planned, "planner found no donations");
}
