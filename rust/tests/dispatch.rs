//! Integration tests for the central op dispatcher: per-key routing,
//! error paths, free profiling, runtime registration, and an F64
//! end-to-end gradcheck (linear → mse_loss → backward).

use torsk::dispatch::{self, DispatchKey, OpCtx, OpDef, Param};
use torsk::ops;
use torsk::prelude::*;
use torsk::tensor::to_f64_vec;

fn panic_message(r: std::thread::Result<Tensor>) -> String {
    match r {
        Ok(_) => panic!("expected a panic"),
        Err(e) => {
            if let Some(s) = e.downcast_ref::<String>() {
                s.clone()
            } else if let Some(s) = e.downcast_ref::<&str>() {
                (*s).to_string()
            } else {
                String::from("<non-string panic>")
            }
        }
    }
}

#[test]
fn routes_per_backend_key() {
    let a = Tensor::from_slice(&[1.0f32, 2.0]);
    let b = Tensor::from_slice(&[3.0f32, 4.0]);
    assert_eq!(dispatch::key_stack(&[&a]), vec![DispatchKey::Cpu]);
    let cpu = ops::add(&a, &b);

    let (sa, sb) = (a.to_sim(), b.to_sim());
    assert_eq!(dispatch::key_stack(&[&sa]), vec![DispatchKey::Sim]);
    let sim = ops::add(&sa, &sb);
    assert_eq!(sim.device(), Device::Sim);
    assert_eq!(cpu.to_vec::<f32>(), sim.to_vec::<f32>());
}

#[test]
fn autograd_is_a_wrapping_key() {
    let a = Tensor::from_slice(&[1.0f32]).requires_grad(true);
    assert_eq!(dispatch::key_stack(&[&a]), vec![DispatchKey::Autograd, DispatchKey::Cpu]);
    // Under no_grad the wrapping key disappears.
    torsk::autograd::no_grad(|| {
        assert_eq!(dispatch::key_stack(&[&a]), vec![DispatchKey::Cpu]);
    });
}

#[test]
fn unknown_op_lists_catalog() {
    let a = Tensor::ones(&[1]);
    let msg = panic_message(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        dispatch::call("frobnicate", &[&a], &[])
    })));
    assert!(msg.contains("no operator named 'frobnicate'"), "msg: {msg}");
    assert!(msg.contains("matmul"), "catalog should list known ops: {msg}");
}

#[test]
fn dtype_mismatch_is_a_schema_error() {
    let idx = Tensor::from_vec(vec![1i64, 2], &[2]);
    let msg = panic_message(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        ops::relu(&idx)
    })));
    assert!(msg.contains("unsupported dtype int64"), "msg: {msg}");
    assert!(msg.contains("float32"), "msg should list supported dtypes: {msg}");
}

#[test]
fn every_op_profiles_for_free() {
    torsk::profiler::start();
    let a = Tensor::from_slice(&[1.0f32, -1.0]);
    let b = Tensor::from_slice(&[2.0f32, 2.0]);
    let _ = ops::add(&a, &b);
    let _ = ops::relu(&a);
    let _ = ops::matmul(&Tensor::ones(&[2, 2]), &Tensor::ones(&[2, 2]));
    let events = torsk::profiler::stop();
    let names: Vec<&str> = events.iter().map(|e| e.name.as_str()).collect();
    for want in ["op:add", "op:relu", "op:matmul"] {
        assert!(names.contains(&want), "missing {want} in {names:?}");
    }
}

#[test]
fn runtime_registration_via_public_api() {
    fn triple(ctx: &OpCtx) -> Tensor {
        ops::mul_scalar(ctx.input(0), 3.0)
    }
    fn triple_samples(seed: u64, dt: DType) -> Option<dispatch::OpSample> {
        let x = dispatch::sample_uniform(seed, &[4], dt, -1.0, 1.0)?;
        Some(dispatch::OpSample { inputs: vec![x], params: vec![], grad_inputs: vec![] })
    }
    dispatch::register_op(
        OpDef::new("itest_triple", 1, 1, &[DType::F32])
            .kernel(DispatchKey::Cpu, triple)
            .kernel(DispatchKey::Sim, triple)
            .sample_inputs(triple_samples),
    );
    assert!(dispatch::has_op("itest_triple"));
    let y = dispatch::call("itest_triple", &[&Tensor::from_slice(&[2.0f32])], &[Param::F32(0.0)]);
    assert_eq!(y.to_vec::<f32>(), vec![6.0]);
    // Runtime ops surface through the OpInfo API like built-ins.
    let info = dispatch::op_info("itest_triple").expect("registered");
    assert!((info.sample)(0, DType::F32).is_some());
}

#[test]
fn f64_elementwise_matmul_backward_end_to_end() {
    // The acceptance-criteria chain: one non-f32 dtype through elementwise
    // + matmul + backward.
    let a = Tensor::from_vec(vec![1.0f64, 2.0, 3.0, 4.0], &[2, 2]).requires_grad(true);
    let b = Tensor::from_vec(vec![0.5f64, 1.5, 2.5, 3.5], &[2, 2]).requires_grad(true);
    let y = ops::mul(&ops::matmul(&a, &b), &b);
    let loss = ops::sum(&y);
    loss.backward();
    assert_eq!(a.grad().unwrap().dtype(), DType::F64);
    assert_eq!(b.grad().unwrap().dtype(), DType::F64);
}

#[test]
fn f64_linear_mse_backward_gradcheck() {
    // linear → mse_loss → backward, checked against central differences
    // at f64 precision (the whole point of the F64 path).
    let xv: Vec<f64> = vec![0.3, -1.2, 0.7, 1.1, 0.05, -0.4, 0.9, -0.8, 0.25, 0.6, -1.5, 0.45];
    let wv: Vec<f64> = vec![0.2, -0.5, 0.8, -0.3, 0.6, 0.1];
    let bv: Vec<f64> = vec![0.05, -0.15];
    let tv: Vec<f64> = vec![0.4, -0.2, 0.1, 0.3, -0.6, 0.2, 0.05, -0.1];

    let x = Tensor::from_vec(xv, &[4, 3]);
    let t = Tensor::from_vec(tv, &[4, 2]);
    let w = Tensor::from_vec(wv.clone(), &[2, 3]).requires_grad(true);
    let b = Tensor::from_vec(bv.clone(), &[2]).requires_grad(true);

    let loss = ops::mse_loss(&ops::linear(&x, &w, Some(&b)), &t);
    assert_eq!(loss.dtype(), DType::F64);
    loss.backward();
    let gw = w.grad().unwrap().to_vec::<f64>();
    let gb = b.grad().unwrap().to_vec::<f64>();

    let eval = |wv: &[f64], bv: &[f64]| -> f64 {
        torsk::autograd::no_grad(|| {
            let w2 = Tensor::from_vec(wv.to_vec(), &[2, 3]);
            let b2 = Tensor::from_vec(bv.to_vec(), &[2]);
            to_f64_vec(&ops::mse_loss(&ops::linear(&x, &w2, Some(&b2)), &t))[0]
        })
    };
    let eps = 1e-6;
    for idx in 0..wv.len() {
        let mut wp = wv.clone();
        wp[idx] += eps;
        let mut wm = wv.clone();
        wm[idx] -= eps;
        let fd = (eval(&wp, &bv) - eval(&wm, &bv)) / (2.0 * eps);
        assert!(
            (gw[idx] - fd).abs() < 1e-7,
            "dW[{idx}]: autograd {} vs finite-diff {fd}",
            gw[idx]
        );
    }
    for idx in 0..bv.len() {
        let mut bp = bv.clone();
        bp[idx] += eps;
        let mut bm = bv.clone();
        bm[idx] -= eps;
        let fd = (eval(&wv, &bp) - eval(&wv, &bm)) / (2.0 * eps);
        assert!(
            (gb[idx] - fd).abs() < 1e-7,
            "db[{idx}]: autograd {} vs finite-diff {fd}",
            gb[idx]
        );
    }
}

#[test]
fn f64_works_on_sim_device_too() {
    let a = Tensor::from_vec(vec![1.0f64, 2.0], &[2]).to_sim();
    let b = Tensor::from_vec(vec![3.0f64, 4.0], &[2]).to_sim();
    let c = ops::mul(&a, &b);
    assert_eq!(c.device(), Device::Sim);
    assert_eq!(c.to_vec::<f64>(), vec![3.0, 8.0]);
}

#[test]
fn registry_is_complete_for_the_public_surface() {
    // Every data-producing public op name must be in the registry.
    for op in [
        "add", "sub", "mul", "div", "maximum", "eq", "neg", "exp", "log", "sqrt", "relu",
        "sigmoid", "tanh", "add_scalar", "mul_scalar", "pow_scalar", "clamp", "cast", "matmul",
        "bmm", "linear", "sum", "sum_dims", "mean", "mean_dims", "max_all", "argmax_dim",
        "softmax", "log_softmax", "cross_entropy", "mse_loss", "bce_loss", "conv2d", "maxpool2d",
        "avgpool2d", "global_avgpool2d", "batch_norm", "batch_norm_train", "layer_norm",
        "dropout", "embedding", "one_hot", "cat", "add_", "sub_", "mul_", "copy_", "axpy_",
        "mul_scalar_", "add_scalar_", "fill_", "fused:gelu", "fused:mse", "fused:bce",
        "fused:sigmoid_bce", "fused:ln_tail", "fused:adam_step", "fused:sgd_step",
    ] {
        assert!(dispatch::has_op(op), "op '{op}' missing from registry");
    }
}
