//! The `debug-checks` runtime sanitizer, end to end: the checks must
//! (a) catch injected invariant violations and (b) stay silent on every
//! real kernel path. Built only with `--features debug-checks` (CI runs
//! this in the thread matrix).
#![cfg(feature = "debug-checks")]

use torsk::debug_checks;
use torsk::prelude::*;

// ------------------------------------------------------------------
// (a) Injected violations are caught
// ------------------------------------------------------------------

/// The core race check: an overlapping split — two chunks claiming the
/// same indices — must abort. `kernels::parallel_for` routes every real
/// split through this same function before submitting work.
#[test]
#[should_panic(expected = "overlapping parallel_for split")]
fn overlapping_split_is_caught() {
    debug_checks::verify_disjoint_cover(1 << 20, &[(0, 600_000), (500_000, 1 << 20)]);
}

#[test]
#[should_panic(expected = "covers")]
fn split_with_gap_is_caught() {
    debug_checks::verify_disjoint_cover(100, &[(0, 40), (60, 100)]);
}

#[test]
#[should_panic(expected = "exceeds n")]
fn split_past_the_end_is_caught() {
    debug_checks::verify_disjoint_cover(100, &[(0, 128)]);
}

#[test]
#[should_panic(expected = "reaches index")]
fn short_fused_operand_is_caught() {
    // A Flat operand of 8 elements cannot serve a 16-element pass.
    debug_checks::verify_access_extent("fused:test", 0, 8, 15);
}

// ------------------------------------------------------------------
// (b) Real kernels run clean under the sanitizer
// ------------------------------------------------------------------

/// Big enough that parallel_for actually splits across the pool
/// (> SERIAL_GRAIN), so the disjointness check sees real multi-chunk
/// splits, not the serial fast path.
const N: usize = 200_000;

#[test]
fn parallel_elementwise_passes_the_sanitizer() {
    torsk::rng::manual_seed(7);
    let a = Tensor::rand(&[N]);
    let b = Tensor::rand(&[N]);
    let c = ops::add(&a, &b);
    let d = ops::mul(&c, &a);
    let s: f32 = d.sum().to_vec::<f32>()[0];
    assert!(s.is_finite());
}

#[test]
fn output_stealing_passes_the_donation_and_aliasing_checks() {
    torsk::rng::manual_seed(8);
    let a = Tensor::rand(&[N]);
    let b = Tensor::rand(&[N]);
    let (_, hits_before) = torsk::dispatch::output_reuse_stats();
    // The owned `+` and `* 0.5` steal the chain buffer — exercising
    // take_donated's liveness check and call_with's aliasing check.
    let t = &a * &b;
    let t = t + &a;
    let y = t * 0.5;
    let (_, hits_after) = torsk::dispatch::output_reuse_stats();
    assert!(hits_after > hits_before, "expected at least one stolen output");
    let v = y.to_vec::<f32>();
    assert!(v.iter().all(|x| x.is_finite()));
}

#[test]
fn fused_tapes_pass_tape_and_extent_verification() {
    torsk::rng::manual_seed(9);
    // softplus/bce-style fused ops route through run_map / run_map_sum,
    // which re-verify the tape and every operand extent.
    let x = Tensor::randn(&[512, 16]).requires_grad(true);
    let t = Tensor::rand(&[512, 16]);
    let loss = ops::bce_with_logits(&x, &t);
    loss.backward();
    let g = x.grad().expect("grad");
    assert_eq!(g.shape(), &[512, 16]);
}

#[test]
fn backward_graph_passes_the_sanitizer() {
    torsk::rng::manual_seed(10);
    let x = Tensor::randn(&[64, 32]);
    let w = Tensor::randn(&[8, 32]).requires_grad(true);
    let y = ops::linear(&x, &w, None).relu();
    let loss = y.mean();
    loss.backward();
    assert_eq!(w.grad().unwrap().shape(), &[8, 32]);
}
