//! Allocator caching + dispatcher output-stealing over real workloads.
//!
//! Lives in its own integration binary (its own process) so the host
//! allocator's counters aren't polluted by unrelated test traffic.

use torsk::alloc::Allocator;
use torsk::nn::{self, Module};
use torsk::ops;
use torsk::optim::{Optimizer, Sgd};
use torsk::Tensor;

fn train_step(model: &nn::Sequential, x: &Tensor, target: &Tensor, opt: &mut Sgd) {
    let loss = ops::mse_loss(&model.forward(x), target);
    opt.zero_grad();
    loss.backward();
    opt.step();
}

#[test]
fn training_loop_hits_allocator_cache() {
    torsk::rng::manual_seed(3);
    let model = nn::Sequential::new()
        .add(nn::Linear::new(64, 32))
        .add(nn::ReLU)
        .add(nn::Linear::new(32, 8));
    let x = Tensor::randn(&[16, 64]);
    let target = Tensor::randn(&[16, 8]);
    let mut opt = Sgd::new(model.parameters(), 0.01);

    // Warm-up steps populate the cache (Figure 2's expensive iteration 1).
    for _ in 0..5 {
        train_step(&model, &x, &target, &mut opt);
    }

    let alloc = torsk::ctx::host_allocator();
    let before = alloc.stats();
    for _ in 0..100 {
        train_step(&model, &x, &target, &mut opt);
    }
    let d = alloc.stats().delta(&before);

    assert!(
        d.cache_hits + d.driver_allocs > 0,
        "expected allocator traffic during the training loop"
    );
    let rate = d.cache_hit_rate();
    assert!(
        rate > 0.5,
        "cache hit rate {rate:.3} <= 50% over 100 training iterations \
         (hits {}, driver allocs {})",
        d.cache_hits,
        d.driver_allocs
    );
}

#[test]
fn inference_chain_steals_output_buffers() {
    let a = Tensor::rand(&[50_000]);
    let b = Tensor::rand(&[50_000]);
    let (_, hits_before) = torsk::dispatch::output_reuse_stats();
    let iters = 10u64;
    for _ in 0..iters {
        // `&a * &b` allocates once; the owned `+` and `* 0.5` both steal
        // the chain buffer, so the whole expression uses one allocation.
        let t = &a * &b;
        let t = t + &a;
        let y = t * 0.5;
        std::hint::black_box(&y);
    }
    let (_, hits_after) = torsk::dispatch::output_reuse_stats();
    assert!(
        hits_after - hits_before >= 2 * iters,
        "expected >= {} stolen outputs, got {}",
        2 * iters,
        hits_after - hits_before
    );
}

#[test]
fn stolen_buffers_produce_correct_values() {
    // The same chain, checked against the borrowing (never-stealing) path.
    let a = Tensor::rand(&[10_000]);
    let b = Tensor::rand(&[10_000]);
    let reference = ops::mul_scalar(&ops::add(&ops::mul(&a, &b), &a), 0.5);
    let owned = (&a * &b + &a) * 0.5;
    assert_eq!(reference.to_vec::<f32>(), owned.to_vec::<f32>());
}
