//! Determinism guarantees of the parallel kernel stack: every reduction
//! must be bit-for-bit identical at `PALLAS_NUM_THREADS` = 1, 2 and 8.
//!
//! The guarantee is structural, not a property of a lucky schedule:
//! row/column reductions give each output element exactly one owner that
//! folds serially in index order, and flat reductions use fixed-width
//! chunks (`iter::REDUCE_CHUNK`) combined in chunk order — nothing ever
//! derives a partial-sum boundary from the thread count. That also makes
//! `set_num_threads` safe to flip concurrently from other tests: these
//! assertions compare values, never timings.

use torsk::kernels::set_num_threads;
use torsk::ops;
use torsk::Tensor;

/// Run `f` at 1, 2 and 8 effective threads, restoring the default after.
fn at_threads<T>(f: impl Fn() -> T) -> Vec<T> {
    let out = [1usize, 2, 8]
        .iter()
        .map(|&t| {
            set_num_threads(t);
            f()
        })
        .collect();
    set_num_threads(0);
    out
}

fn assert_all_equal(results: &[Vec<f32>], what: &str) {
    assert_eq!(results[0], results[1], "{what}: 1 vs 2 threads differ");
    assert_eq!(results[0], results[2], "{what}: 1 vs 8 threads differ");
}

#[test]
fn sum_dims_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(7);
    // Trailing-dim reduction (row path) — large enough to actually split.
    let a = Tensor::randn(&[96, 1539]);
    let rows = at_threads(|| ops::sum_dims(&a, &[1], false).to_vec::<f32>());
    assert_all_equal(&rows, "sum_dims rows");

    // Leading-dim reduction (column-accumulate path).
    let b = Tensor::randn(&[513, 640]);
    let cols = at_threads(|| ops::sum_dims(&b, &[0], false).to_vec::<f32>());
    assert_all_equal(&cols, "sum_dims cols");
}

#[test]
fn full_sum_and_mean_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(11);
    // Several REDUCE_CHUNKs plus a ragged tail.
    let a = Tensor::randn(&[(1 << 20) + 17]);
    let sums = at_threads(|| ops::sum(&a).to_vec::<f32>());
    assert_all_equal(&sums, "sum");
    let means = at_threads(|| ops::mean(&a).to_vec::<f32>());
    assert_all_equal(&means, "mean");
}

#[test]
fn softmax_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(13);
    let x = Tensor::randn(&[333, 257]);
    let y = at_threads(|| ops::softmax_last(&x).to_vec::<f32>());
    assert_all_equal(&y, "softmax");
    let ly = at_threads(|| ops::log_softmax_last(&x).to_vec::<f32>());
    assert_all_equal(&ly, "log_softmax");
}

#[test]
fn mse_loss_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(17);
    let pred = Tensor::randn(&[1 << 18]);
    let target = Tensor::randn(&[1 << 18]);
    let losses = at_threads(|| ops::mse_loss(&pred, &target).to_vec::<f32>());
    assert_all_equal(&losses, "mse_loss");
}

#[test]
fn cross_entropy_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(19);
    // More rows than one 4096-row loss chunk, so partials really combine.
    let logits = Tensor::randn(&[9000, 16]);
    let targets = Tensor::randint(16, &[9000]);
    let losses = at_threads(|| ops::cross_entropy(&logits, &targets).to_vec::<f32>());
    assert_all_equal(&losses, "cross_entropy");
}

#[test]
fn layer_norm_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(23);
    let x = Tensor::randn(&[64, 2048]);
    let gamma = Tensor::ones(&[2048]);
    let beta = Tensor::zeros(&[2048]);
    let y = at_threads(|| ops::layer_norm(&x, &gamma, &beta, 1e-5).to_vec::<f32>());
    assert_all_equal(&y, "layer_norm");
}

#[test]
fn elementwise_and_broadcast_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(29);
    let a = Tensor::randn(&[200_000]);
    let b = Tensor::randn(&[200_000]);
    let y = at_threads(|| ops::mul(&a, &b).to_vec::<f32>());
    assert_all_equal(&y, "mul");
    let m = Tensor::randn(&[391, 512]);
    let v = Tensor::randn(&[512]);
    let s = at_threads(|| ops::add(&m, &v).to_vec::<f32>());
    assert_all_equal(&s, "broadcast add");
}

#[test]
fn backward_gradients_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(31);
    let x = Tensor::randn(&[128, 513]);
    let w = Tensor::randn(&[513]);
    let grads = at_threads(|| {
        // Fresh leaf per run (shared data, fresh autograd metadata).
        let leaf = x.detach().requires_grad(true);
        let y = ops::mul(&leaf, &w); // broadcast
        ops::sum(&y).backward();
        leaf.grad().unwrap().to_vec::<f32>()
    });
    assert_all_equal(&grads, "broadcast-mul backward");
}
