//! Determinism guarantees of the parallel kernel stack: every reduction
//! must be bit-for-bit identical at `PALLAS_NUM_THREADS` = 1, 2 and 8.
//!
//! The guarantee is structural, not a property of a lucky schedule:
//! row/column reductions give each output element exactly one owner that
//! folds serially in index order, and flat reductions use fixed-width
//! chunks (`iter::REDUCE_CHUNK`) combined in chunk order — nothing ever
//! derives a partial-sum boundary from the thread count. That also makes
//! `set_num_threads` safe to flip concurrently from other tests: these
//! assertions compare values, never timings.

use torsk::autograd::engine::set_backward_threads;
use torsk::kernels::set_num_threads;
use torsk::ops;
use torsk::Tensor;

/// Run `f` at 1, 2 and 8 effective threads, restoring the default after.
fn at_threads<T>(f: impl Fn() -> T) -> Vec<T> {
    let out = [1usize, 2, 8]
        .iter()
        .map(|&t| {
            set_num_threads(t);
            f()
        })
        .collect();
    set_num_threads(0);
    out
}

/// Run `f` over the full thread matrix: kernel pool 1/2/8 × backward
/// engine 1/8 (the same axes the CI thread-matrix job sweeps via
/// `PALLAS_NUM_THREADS` × `TORSK_BACKWARD_THREADS`).
fn at_thread_matrix<T>(f: impl Fn() -> T) -> Vec<T> {
    let mut out = Vec::new();
    for &bw in &[1usize, 8] {
        set_backward_threads(bw);
        for &t in &[1usize, 2, 8] {
            set_num_threads(t);
            out.push(f());
        }
    }
    set_num_threads(0);
    set_backward_threads(0);
    out
}

fn assert_matrix_equal(results: &[(Vec<f32>, Vec<Vec<f32>>)], what: &str) {
    for (i, r) in results.iter().enumerate().skip(1) {
        assert_eq!(&results[0], r, "{what}: thread-matrix cell {i} differs from cell 0");
    }
}

fn assert_all_equal(results: &[Vec<f32>], what: &str) {
    assert_eq!(results[0], results[1], "{what}: 1 vs 2 threads differ");
    assert_eq!(results[0], results[2], "{what}: 1 vs 8 threads differ");
}

#[test]
fn sum_dims_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(7);
    // Trailing-dim reduction (row path) — large enough to actually split.
    let a = Tensor::randn(&[96, 1539]);
    let rows = at_threads(|| ops::sum_dims(&a, &[1], false).to_vec::<f32>());
    assert_all_equal(&rows, "sum_dims rows");

    // Leading-dim reduction (column-accumulate path).
    let b = Tensor::randn(&[513, 640]);
    let cols = at_threads(|| ops::sum_dims(&b, &[0], false).to_vec::<f32>());
    assert_all_equal(&cols, "sum_dims cols");
}

#[test]
fn full_sum_and_mean_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(11);
    // Several REDUCE_CHUNKs plus a ragged tail.
    let a = Tensor::randn(&[(1 << 20) + 17]);
    let sums = at_threads(|| ops::sum(&a).to_vec::<f32>());
    assert_all_equal(&sums, "sum");
    let means = at_threads(|| ops::mean(&a).to_vec::<f32>());
    assert_all_equal(&means, "mean");
}

#[test]
fn softmax_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(13);
    let x = Tensor::randn(&[333, 257]);
    let y = at_threads(|| ops::softmax_last(&x).to_vec::<f32>());
    assert_all_equal(&y, "softmax");
    let ly = at_threads(|| ops::log_softmax_last(&x).to_vec::<f32>());
    assert_all_equal(&ly, "log_softmax");
}

#[test]
fn mse_loss_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(17);
    let pred = Tensor::randn(&[1 << 18]);
    let target = Tensor::randn(&[1 << 18]);
    let losses = at_threads(|| ops::mse_loss(&pred, &target).to_vec::<f32>());
    assert_all_equal(&losses, "mse_loss");
}

#[test]
fn cross_entropy_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(19);
    // More rows than one 4096-row loss chunk, so partials really combine.
    let logits = Tensor::randn(&[9000, 16]);
    let targets = Tensor::randint(16, &[9000]);
    let losses = at_threads(|| ops::cross_entropy(&logits, &targets).to_vec::<f32>());
    assert_all_equal(&losses, "cross_entropy");
}

#[test]
fn layer_norm_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(23);
    let x = Tensor::randn(&[64, 2048]);
    let gamma = Tensor::ones(&[2048]);
    let beta = Tensor::zeros(&[2048]);
    let y = at_threads(|| ops::layer_norm(&x, &gamma, &beta, 1e-5).to_vec::<f32>());
    assert_all_equal(&y, "layer_norm");
}

#[test]
fn elementwise_and_broadcast_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(29);
    let a = Tensor::randn(&[200_000]);
    let b = Tensor::randn(&[200_000]);
    let y = at_threads(|| ops::mul(&a, &b).to_vec::<f32>());
    assert_all_equal(&y, "mul");
    let m = Tensor::randn(&[391, 512]);
    let v = Tensor::randn(&[512]);
    let s = at_threads(|| ops::add(&m, &v).to_vec::<f32>());
    assert_all_equal(&s, "broadcast add");
}

/// Loss + input gradients of `f` on fresh leaves (shared data, fresh
/// autograd metadata per run).
fn fwd_bwd(inputs: &[Tensor], f: impl Fn(&[Tensor]) -> Tensor) -> (Vec<f32>, Vec<Vec<f32>>) {
    let leaves: Vec<Tensor> = inputs.iter().map(|t| t.detach().requires_grad(true)).collect();
    let loss = f(&leaves);
    loss.backward();
    (
        loss.to_vec::<f32>(),
        leaves.iter().map(|l| l.grad().expect("grad flows").to_vec::<f32>()).collect(),
    )
}

#[test]
fn fused_losses_fwd_bwd_bitwise_equal_across_thread_matrix() {
    torsk::rng::manual_seed(37);
    // Big enough to split across the kernel pool and cross REDUCE_CHUNK.
    let x = Tensor::randn(&[(1 << 17) + 331]);
    let t = Tensor::rand(&[(1 << 17) + 331]);

    let inputs = [x.clone(), t.clone()];
    let mse = at_thread_matrix(|| fwd_bwd(&inputs, |l| ops::mse_loss(&l[0], &l[1])));
    assert_matrix_equal(&mse, "fused:mse fwd+bwd");

    let sbce = at_thread_matrix(|| fwd_bwd(&inputs, |l| ops::bce_with_logits(&l[0], &l[1])));
    assert_matrix_equal(&sbce, "fused:sigmoid_bce fwd+bwd");

    let probs = [ops::sigmoid(&x), t.clone()];
    let bce = at_thread_matrix(|| fwd_bwd(&probs, |l| ops::bce_loss(&l[0], &l[1])));
    assert_matrix_equal(&bce, "fused:bce fwd+bwd");
}

#[test]
fn fused_gelu_fwd_bwd_bitwise_equal_across_thread_matrix() {
    torsk::rng::manual_seed(41);
    let x = Tensor::randn(&[(1 << 17) + 77]);
    let r = at_thread_matrix(|| fwd_bwd(&[x.clone()], |l| ops::sum(&ops::gelu(&l[0]))));
    assert_matrix_equal(&r, "fused:gelu fwd+bwd");
}

#[test]
fn layer_norm_fwd_bwd_bitwise_equal_across_thread_matrix() {
    // The full layer-norm graph: deterministic row reductions for the
    // statistics plus the fused:ln_tail node, forward and backward, at
    // every kernel × backward thread combination.
    torsk::rng::manual_seed(43);
    let x = Tensor::randn(&[96, 768]);
    let gamma = Tensor::randn(&[768]);
    let beta = Tensor::randn(&[768]);
    let r = at_thread_matrix(|| {
        fwd_bwd(&[x.clone(), gamma.clone(), beta.clone()], |l| {
            ops::sum(&ops::layer_norm(&l[0], &l[1], &l[2], 1e-5))
        })
    });
    assert_matrix_equal(&r, "layer_norm fwd+bwd");
}

#[test]
fn optimizer_steps_bitwise_equal_across_thread_matrix() {
    torsk::rng::manual_seed(47);
    let w0 = Tensor::randn(&[50_000]);
    let x = Tensor::randn(&[50_000]);
    let t = Tensor::randn(&[50_000]);
    // Two optimization steps end-to-end: forward, backward, fused update.
    let run = |adam: bool| {
        // Deep copy: the fused steps mutate the param in place, so each
        // matrix cell must start from untouched data.
        let w = Tensor::from_vec(w0.to_vec::<f32>(), w0.shape()).requires_grad(true);
        let mut sgd = torsk::optim::Sgd::new(vec![w.clone()], 0.05).with_momentum(0.9);
        let mut ad = torsk::optim::Adam::new(vec![w.clone()], 1e-3);
        for _ in 0..2 {
            let loss = ops::mse_loss(&ops::mul(&w, &x), &t);
            if adam {
                ad.zero_grad();
                loss.backward();
                ad.step();
            } else {
                sgd.zero_grad();
                loss.backward();
                sgd.step();
            }
        }
        w.detach().to_vec::<f32>()
    };
    for adam in [false, true] {
        let results = at_thread_matrix(|| (run(adam), Vec::<Vec<f32>>::new()));
        assert_matrix_equal(&results, if adam { "fused:adam_step" } else { "fused:sgd_step" });
    }
}

#[test]
fn packed_gemm_bitwise_equal_across_thread_counts() {
    // The packed BLIS-style GEMM: the tile grid and k-panel order derive
    // only from (m, n, k) and fixed blocking constants, so every thread
    // count must produce identical bits. Shape crosses the MC/KC/NC
    // block boundaries.
    use torsk::kernels::matmul::{sgemm, Trans, KC, MC, NC};
    let (m, n, k) = (MC + 13, NC + 21, KC + 7);
    torsk::rng::manual_seed(53);
    let a = Tensor::randn(&[m, k]).to_vec::<f32>();
    let b = Tensor::randn(&[k, n]).to_vec::<f32>();
    for &(ta, tb) in &[(Trans::N, Trans::N), (Trans::T, Trans::T)] {
        let run = at_threads(|| {
            let mut c = vec![0.0f32; m * n];
            sgemm(ta, tb, m, n, k, 1.0, &a, &b, 0.0, &mut c);
            c
        });
        assert_all_equal(&run, "packed sgemm");
    }
}

#[test]
fn matmul_linear_fwd_bwd_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(59);
    let x = Tensor::randn(&[96, 130]);
    let w = Tensor::randn(&[70, 130]);
    let b = Tensor::randn(&[70]);
    let inputs = [x, w, b];
    let lin = at_threads(|| {
        fwd_bwd(&inputs, |l| ops::sum(&ops::linear(&l[0], &l[1], Some(&l[2]))))
    });
    for (i, r) in lin.iter().enumerate().skip(1) {
        assert_eq!(&lin[0], r, "linear fwd+bwd: thread cell {i} differs");
    }
    let mm = at_threads(|| {
        fwd_bwd(&inputs[..2], |l| ops::sum(&ops::matmul(&l[0], &l[1].t())))
    });
    for (i, r) in mm.iter().enumerate().skip(1) {
        assert_eq!(&mm[0], r, "transposed matmul fwd+bwd: thread cell {i} differs");
    }
}

#[test]
fn batched_gemm_bitwise_equal_across_thread_counts() {
    // sgemm_batched parallelizes over the batch dim; dgemm_batched now
    // does too. Both must be schedule-invariant.
    torsk::rng::manual_seed(61);
    let a = Tensor::randn(&[16, 24, 40]);
    let b = Tensor::randn(&[16, 40, 32]);
    let f32_runs = at_threads(|| ops::bmm(&a, &b).to_vec::<f32>());
    assert_all_equal(&f32_runs, "bmm f32");
    let a64 = a.to_dtype(torsk::tensor::DType::F64);
    let b64 = b.to_dtype(torsk::tensor::DType::F64);
    let f64_runs = at_threads(|| ops::bmm(&a64, &b64).to_vec::<f64>());
    assert_eq!(f64_runs[0], f64_runs[1], "bmm f64: 1 vs 2 threads differ");
    assert_eq!(f64_runs[0], f64_runs[2], "bmm f64: 1 vs 8 threads differ");
}

#[test]
fn backward_gradients_bitwise_equal_across_thread_counts() {
    torsk::rng::manual_seed(31);
    let x = Tensor::randn(&[128, 513]);
    let w = Tensor::randn(&[513]);
    let grads = at_threads(|| {
        // Fresh leaf per run (shared data, fresh autograd metadata).
        let leaf = x.detach().requires_grad(true);
        let y = ops::mul(&leaf, &w); // broadcast
        ops::sum(&y).backward();
        leaf.grad().unwrap().to_vec::<f32>()
    });
    assert_all_equal(&grads, "broadcast-mul backward");
}
