//! Data-pipeline guarantees, in the `parallel_determinism.rs` spirit:
//!
//! 1. **Worker-count invariance** — the same seed yields a bitwise-
//!    identical batch sequence at workers 0, 1 and 4 (ordered reassembly
//!    over the bounded prefetch queue, sampler decided up front).
//! 2. **Clean shutdown** — dropping an epoch iterator mid-epoch joins all
//!    worker threads promptly; nobody deadlocks on the full queue, and
//!    the loader is immediately reusable.
//! 3. **Buffer reuse** — steady-state collated batches come out of the
//!    caching allocator's cache (the paper's pinned-buffer reuse), not
//!    fresh driver allocations.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use torsk::data::{Collate, DataLoader, Dataset, Sampler, SyntheticImages, SyntheticInteractions};
use torsk::tensor::Tensor;

/// Serializes the tests in this binary: the buffer-cache test reads the
/// process-global host-allocator counters, which concurrent loader tests
/// would pollute.
static SERIAL: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

type Fingerprint = Vec<(Vec<f32>, Vec<i64>)>;

fn image_epoch(workers: usize, seed: u64) -> Fingerprint {
    let ds = Arc::new(SyntheticImages::new(64, 3, 8, 8, 10));
    let dl = DataLoader::new(ds, 8).shuffle(true).seed(seed).workers(workers);
    dl.iter().map(|(x, y)| (x.to_vec::<f32>(), y.to_vec::<i64>())).collect()
}

#[test]
fn batch_sequence_bitwise_identical_across_worker_counts() {
    let _g = guard();
    let reference = image_epoch(0, 5);
    assert_eq!(reference.len(), 8, "64 examples / batch 8");
    for workers in [1usize, 4] {
        let got = image_epoch(workers, 5);
        assert_eq!(
            got, reference,
            "batch stream at workers={workers} must be bitwise identical to workers=0"
        );
    }
    // A different seed must actually change the stream (the pin is not
    // vacuous).
    assert_ne!(image_epoch(0, 6), reference);
}

#[test]
fn mixed_dtype_targets_survive_worker_roundtrip() {
    let _g = guard();
    // NCF-style: i64 pair inputs, f32 click labels.
    let ds = Arc::new(SyntheticInteractions::new(48, 10, 10));
    let serial: Vec<(Vec<i64>, Vec<f32>)> = DataLoader::new(ds.clone(), 6)
        .iter()
        .map(|(x, y)| (x.to_vec::<i64>(), y.to_vec::<f32>()))
        .collect();
    let parallel: Vec<(Vec<i64>, Vec<f32>)> = DataLoader::new(ds, 6)
        .workers(4)
        .iter()
        .map(|(x, y)| (x.to_vec::<i64>(), y.to_vec::<f32>()))
        .collect();
    assert_eq!(serial, parallel);
    assert_eq!(serial[0].1.len(), 6, "f32 [1] targets collate to [N,1]");
}

/// A dataset slow enough that workers are mid-batch (or blocked on the
/// full prefetch queue) when the consumer walks away.
struct Slow {
    fetches: Arc<AtomicUsize>,
}

impl Dataset for Slow {
    fn len(&self) -> usize {
        256
    }
    fn get(&self, i: usize) -> (Tensor, Tensor) {
        self.fetches.fetch_add(1, Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(2));
        (Tensor::full(&[4], i as f32), Tensor::from_vec(vec![i as i64], &[]))
    }
}

#[test]
fn drop_mid_epoch_joins_workers_without_deadlock() {
    let _g = guard();
    let fetches = Arc::new(AtomicUsize::new(0));
    let ds = Arc::new(Slow { fetches: fetches.clone() });
    let dl = DataLoader::new(ds, 4).workers(4);

    let mut it = dl.iter();
    let a = it.next().expect("first batch");
    let b = it.next().expect("second batch");
    assert_eq!(a.0.shape(), &[4, 4]);
    assert_eq!(b.1.to_vec::<i64>(), vec![4, 5, 6, 7]);

    // Tear the epoch down mid-flight. Drop must join all four workers:
    // each is at worst one 4-sample batch (~8ms) from its send, which
    // errors out the moment the receiver disappears.
    let t0 = Instant::now();
    drop(it);
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(5),
        "drop should join workers promptly, took {elapsed:?}"
    );

    // No worker survived to keep fetching.
    let after_drop = fetches.load(Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(
        fetches.load(Ordering::SeqCst),
        after_drop,
        "no dataset fetches after the iterator was dropped"
    );
    // Far fewer than the full epoch was ever fetched.
    assert!(after_drop < 256, "tear-down should not have drained the epoch");

    // The loader is immediately reusable for a full, correct epoch.
    let fresh = Arc::new(Slow { fetches: Arc::new(AtomicUsize::new(0)) });
    let ys: Vec<i64> = DataLoader::new(fresh, 64)
        .workers(4)
        .iter()
        .flat_map(|(_, y)| y.to_vec::<i64>())
        .collect();
    assert_eq!(ys, (0..256).collect::<Vec<i64>>());
}

#[test]
#[should_panic(expected = "worker thread panicked mid-epoch")]
fn worker_panic_propagates_instead_of_truncating_the_epoch() {
    let _g = guard();
    struct Poisoned;
    impl Dataset for Poisoned {
        fn len(&self) -> usize {
            32
        }
        fn get(&self, i: usize) -> (Tensor, Tensor) {
            assert!(i != 17, "poisoned example");
            (Tensor::full(&[2], i as f32), Tensor::from_vec(vec![i as i64], &[]))
        }
    }
    // At workers=0 the dataset's own panic surfaces; at workers>=1 the
    // consumer must fail just as loudly, never yield a short epoch.
    let dl = DataLoader::new(Arc::new(Poisoned), 4).workers(2);
    let n = dl.iter().count();
    panic!("unreachable: epoch silently truncated to {n} batches");
}

#[test]
fn steady_state_batches_hit_the_buffer_cache() {
    let _g = guard();
    use torsk::alloc::Allocator;
    let ds = Arc::new(SyntheticImages::new(32, 3, 16, 16, 10));
    let dl = DataLoader::new(ds, 8).shuffle(true).seed(3);

    // Warm-up epochs populate the cache with the batch-buffer sizes.
    for _ in 0..2 {
        for (x, _) in dl.iter() {
            std::hint::black_box(&x);
        }
    }
    let alloc = torsk::ctx::host_allocator();
    let before = alloc.stats();
    for _ in 0..5 {
        for (x, y) in dl.iter() {
            std::hint::black_box((&x, &y));
        }
    }
    let d = alloc.stats().delta(&before);
    assert!(
        d.cache_hits + d.driver_allocs > 0,
        "expected allocator traffic while collating batches"
    );
    let rate = d.cache_hit_rate();
    assert!(
        rate > 0.5,
        "steady-state collate should reuse cached batch buffers: hit rate {rate:.3} \
         (hits {}, driver allocs {})",
        d.cache_hits,
        d.driver_allocs
    );
}

#[test]
fn stall_time_is_accounted_per_loader() {
    let _g = guard();
    let ds = Arc::new(SyntheticImages::new(32, 3, 8, 8, 10));
    let dl = DataLoader::new(ds, 8);
    let before = dl.stats();
    let n = dl.iter().count();
    let d = dl.stats().delta(&before);
    assert_eq!(n, 4);
    assert_eq!(d.batches, 4);
    assert!(d.stall_ns > 0, "workers=0 collates in-line: all data time is stall");
}

#[test]
fn custom_sampler_and_collate_plug_in() {
    let _g = guard();

    /// Reverse sequential order — a custom epoch policy.
    struct Reverse;
    impl Sampler for Reverse {
        fn order(&self, len: usize, _epoch: usize) -> Vec<usize> {
            (0..len).rev().collect()
        }
    }

    /// Collate that also scales inputs by 2 — a custom assembly step.
    struct Doubling;
    impl Collate for Doubling {
        fn collate(&self, samples: &[(Tensor, Tensor)]) -> (Tensor, Tensor) {
            let (x, y) = torsk::data::DefaultCollate.collate(samples);
            (torsk::ops::mul_scalar(&x, 2.0), y)
        }
    }

    struct Tiny;
    impl Dataset for Tiny {
        fn len(&self) -> usize {
            6
        }
        fn get(&self, i: usize) -> (Tensor, Tensor) {
            (Tensor::full(&[2], i as f32), Tensor::from_vec(vec![i as i64], &[]))
        }
    }

    for workers in [0usize, 2] {
        let dl = DataLoader::new(Arc::new(Tiny), 3)
            .sampler(Arc::new(Reverse))
            .collate(Arc::new(Doubling))
            .workers(workers);
        let batches: Vec<(Vec<f32>, Vec<i64>)> =
            dl.iter().map(|(x, y)| (x.to_vec::<f32>(), y.to_vec::<i64>())).collect();
        assert_eq!(
            batches,
            vec![
                (vec![10.0, 10.0, 8.0, 8.0, 6.0, 6.0], vec![5, 4, 3]),
                (vec![4.0, 4.0, 2.0, 2.0, 0.0, 0.0], vec![2, 1, 0]),
            ],
            "workers={workers}"
        );
    }
}
