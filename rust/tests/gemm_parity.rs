//! Parity and invariants of the packed BLIS-style GEMM core.
//!
//! Three guarantees, each pinned here and swept by the CI thread-matrix
//! job (`make gemm-parity`):
//!
//! 1. **Correctness**: the packed kernel matches the naive f64-
//!    accumulating oracle over all four (TransA, TransB) combos, at odd /
//!    tall-skinny / blocking-boundary shapes, at 1, 2 and 8 threads.
//! 2. **Determinism**: packed results are bit-for-bit identical across
//!    thread counts (the tile grid and k order never depend on workers).
//! 3. **No materialization**: the dispatch layer feeds transposed
//!    operands to the kernels as strided views — zero copies, zero
//!    packed-weight repacks after the first `linear` forward — asserted
//!    through `dispatch::gemm_materialization_stats` and
//!    `dispatch::packed_weight_stats`.

use torsk::kernels::matmul::{
    dgemm, matmul_ref_t, pack_b_f32, sgemm, sgemm_prepacked, Trans, KC, MC, NC,
};
use torsk::kernels::set_num_threads;
use torsk::kernels::simd::{detected_level, set_force_scalar, SimdLevel};
use torsk::{dispatch, nn, ops, Tensor};

/// `packed_weight_stats` is process-global; every test that routes
/// through `ops::linear` takes this lock so the deltas it asserts on
/// can't interleave with another test's packs.
static LINEAR_STATS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
    // Simple deterministic LCG — keeps this test free of crate-internal
    // RNG plumbing.
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
        })
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&x, &y)) in got.iter().zip(want.iter()).enumerate() {
        assert!((x - y).abs() <= tol + tol * y.abs(), "{what} idx {i}: {x} vs {y}");
    }
}

/// The acceptance sweep: all four trans combos × odd / tall-skinny /
/// KC-and-MC/NC-boundary shapes × threads 1/2/8, each cell checked
/// against the oracle AND bit-compared across thread counts.
#[test]
fn packed_gemm_all_trans_shapes_threads() {
    let shapes: &[(usize, usize, usize)] = &[
        (5, 7, 11),          // odd
        (2, 65, 300),        // tall-skinny (m « n, k)
        (100, 3, 17),        // skinny-n
        (8, 8, KC + 3),      // KC boundary
        (MC + 1, 33, 40),    // MC boundary
        (3, NC + 5, 29),     // NC boundary
    ];
    let mut seed = 1000;
    for &ta in &[Trans::N, Trans::T] {
        for &tb in &[Trans::N, Trans::T] {
            for &(m, n, k) in shapes {
                seed += 1;
                let a = rand_vec(seed, m * k);
                let b = rand_vec(seed ^ 0xABCD, k * n);
                let expect = matmul_ref_t(ta, tb, m, n, k, &a, &b);
                let mut results: Vec<Vec<f32>> = Vec::new();
                for &t in &[1usize, 2, 8] {
                    set_num_threads(t);
                    let mut c = vec![0.0f32; m * n];
                    sgemm(ta, tb, m, n, k, 1.0, &a, &b, 0.0, &mut c);
                    results.push(c);
                }
                set_num_threads(0);
                let what = format!("({ta:?},{tb:?}) ({m},{n},{k})");
                assert_close(&results[0], &expect, 1e-4, &what);
                assert_eq!(results[0], results[1], "{what}: 1 vs 2 threads differ");
                assert_eq!(results[0], results[2], "{what}: 1 vs 8 threads differ");
            }
        }
    }
}

/// The tentpole invariant: the vector microkernel path and the forced-
/// scalar path produce identical bits for every trans combo × shape, and
/// the threads 1/2/8 pins hold in both modes. Runs under any detected
/// level — when the probe reports Scalar (no AVX2, Miri, or the process
/// was started with `PALLAS_SIMD=0`), both "modes" are the scalar
/// interpreter and the comparison is trivially (but still) checked.
#[test]
fn simd_and_forced_scalar_gemm_bitwise_identical() {
    if detected_level() == SimdLevel::Scalar {
        eprintln!("note: no vector unit active; scalar-vs-scalar run");
    }
    let shapes: &[(usize, usize, usize)] = &[
        (5, 7, 11),
        (2, 65, 300),
        (8, 8, KC + 3),
        (MC + 1, 33, 40),
        (3, NC + 5, 29),
    ];
    let mut seed = 9000;
    for &ta in &[Trans::N, Trans::T] {
        for &tb in &[Trans::N, Trans::T] {
            for &(m, n, k) in shapes {
                seed += 1;
                let a = rand_vec(seed, m * k);
                let b = rand_vec(seed ^ 0x5A5A, k * n);
                let what = format!("({ta:?},{tb:?}) ({m},{n},{k})");
                let mut per_mode: Vec<Vec<u32>> = Vec::new();
                for &force_scalar in &[false, true] {
                    set_force_scalar(force_scalar);
                    let mode = if force_scalar { "scalar" } else { "simd" };
                    let mut per_thread: Vec<Vec<u32>> = Vec::new();
                    for &t in &[1usize, 2, 8] {
                        set_num_threads(t);
                        let mut c = vec![0.0f32; m * n];
                        sgemm(ta, tb, m, n, k, 1.0, &a, &b, 0.0, &mut c);
                        per_thread.push(c.iter().map(|x| x.to_bits()).collect());
                    }
                    set_num_threads(0);
                    assert_eq!(per_thread[0], per_thread[1], "{what} [{mode}]: 1 vs 2 threads");
                    assert_eq!(per_thread[0], per_thread[2], "{what} [{mode}]: 1 vs 8 threads");
                    per_mode.push(per_thread.swap_remove(0));
                }
                set_force_scalar(false);
                assert_eq!(per_mode[0], per_mode[1], "{what}: simd and scalar bits differ");
            }
        }
    }
}

/// f64 twin of the cross-mode pin (4×4 `__m256d`/`float64x2_t` tiles),
/// plus the prepacked-B entry point, which shares the microkernel.
#[test]
fn simd_and_forced_scalar_dgemm_and_prepacked_identical() {
    let (m, n, k) = (MC + 3, NC + 7, KC + 5);
    let a32 = rand_vec(41, m * k);
    let b32 = rand_vec(42, k * n);
    let a: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
    let b: Vec<f64> = b32.iter().map(|&x| x as f64).collect();
    let packed = pack_b_f32(Trans::N, k, n, &b32);

    let mut d_modes: Vec<Vec<u64>> = Vec::new();
    let mut p_modes: Vec<Vec<u32>> = Vec::new();
    for &force_scalar in &[false, true] {
        set_force_scalar(force_scalar);
        let mut c = vec![0.0f64; m * n];
        dgemm(Trans::N, Trans::T, m, n, k, 1.0, &a, &b, 0.0, &mut c);
        d_modes.push(c.iter().map(|x| x.to_bits()).collect());
        let mut cp = vec![0.0f32; m * n];
        sgemm_prepacked(m, n, k, 1.0, &a32, k, 1, &packed, 0.0, &mut cp);
        p_modes.push(cp.iter().map(|x| x.to_bits()).collect());
    }
    set_force_scalar(false);
    assert_eq!(d_modes[0], d_modes[1], "dgemm: simd and scalar bits differ");
    assert_eq!(p_modes[0], p_modes[1], "sgemm_prepacked: simd and scalar bits differ");
}

#[test]
fn dgemm_trans_combos_match_oracle() {
    let (m, n, k) = (13, 21, 67);
    let mut seed = 5000;
    for &ta in &[Trans::N, Trans::T] {
        for &tb in &[Trans::N, Trans::T] {
            seed += 1;
            let a32 = rand_vec(seed, m * k);
            let b32 = rand_vec(seed ^ 0xF00, k * n);
            let a: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
            let b: Vec<f64> = b32.iter().map(|&x| x as f64).collect();
            let mut c = vec![0.0f64; m * n];
            dgemm(ta, tb, m, n, k, 1.0, &a, &b, 0.0, &mut c);
            let expect = matmul_ref_t(ta, tb, m, n, k, &a32, &b32);
            for (i, (&x, &y)) in c.iter().zip(expect.iter()).enumerate() {
                assert!(
                    (x as f32 - y).abs() <= 1e-4 + 1e-4 * y.abs(),
                    "({ta:?},{tb:?}) idx {i}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn prepacked_weight_bit_identical_to_on_the_fly() {
    let (m, n, k) = (19, NC + 9, KC + 17);
    let a = rand_vec(7, m * k);
    let b = rand_vec(8, k * n);
    let mut c1 = vec![0.0f32; m * n];
    sgemm(Trans::N, Trans::N, m, n, k, 1.0, &a, &b, 0.0, &mut c1);
    let packed = pack_b_f32(Trans::N, k, n, &b);
    for &t in &[1usize, 2, 8] {
        set_num_threads(t);
        let mut c2 = vec![0.0f32; m * n];
        sgemm_prepacked(m, n, k, 1.0, &a, k, 1, &packed, 0.0, &mut c2);
        assert_eq!(c1, c2, "prepacked differs at {t} threads");
    }
    set_num_threads(0);
}

/// Dispatch-level transpose-awareness: matmul / linear / bmm forward and
/// backward over transposed views must (a) produce the same values as
/// materialized layouts and (b) never copy an operand —
/// `gemm_materialization_stats` stays zero.
#[test]
fn dispatch_gemm_never_materializes_transposes() {
    let _guard = LINEAR_STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let before = dispatch::gemm_materialization_stats();

    torsk::rng::manual_seed(17);
    // matmul fwd+bwd with a transposed left operand.
    let at = Tensor::randn(&[9, 6]).requires_grad(true); // Aᵀ layout
    let b = Tensor::randn(&[9, 5]).requires_grad(true);
    let y = ops::matmul(&at.t(), &b);
    let y_ref = torsk::autograd::no_grad(|| ops::matmul(&at.t().contiguous(), &b.detach()));
    torsk::tensor::assert_close(&y, &y_ref, 1e-6, 1e-6);
    ops::sum(&y).backward();
    assert!(at.grad().is_some() && b.grad().is_some());

    // linear fwd+bwd (its backward needs Gᵀ @ x).
    let x = Tensor::randn(&[8, 12]).requires_grad(true);
    let w = Tensor::randn(&[4, 12]).requires_grad(true);
    let bias = Tensor::randn(&[4]).requires_grad(true);
    ops::sum(&ops::linear(&x, &w, Some(&bias))).backward();
    assert_eq!(w.grad().unwrap().shape(), &[4, 12]);

    // bmm fwd+bwd (its backward needs per-batch transposes), plus value
    // parity for a transposed 3-D view consumed in place.
    let p = Tensor::randn(&[3, 4, 6]).requires_grad(true);
    let q = Tensor::randn(&[3, 6, 2]).requires_grad(true);
    ops::sum(&ops::bmm(&p, &q)).backward();
    assert_eq!(p.grad().unwrap().shape(), &[3, 4, 6]);
    let pt = Tensor::randn(&[3, 6, 4]); // holds Pᵀ per batch
    let r = Tensor::randn(&[3, 6, 2]);
    let via_view = ops::bmm(&pt.transpose(1, 2), &r);
    let via_copy = ops::bmm(&pt.transpose(1, 2).contiguous(), &r);
    torsk::tensor::assert_close(&via_view, &via_copy, 1e-6, 1e-6);

    assert_eq!(
        dispatch::gemm_materialization_stats(),
        before,
        "a linalg path materialized a GEMM operand"
    );
}

// The runtime counter above only fires if a fallback copy path exists.
// The source-level half of the invariant — `dispatch/linalg.rs` and the
// kernel files must not call `.contiguous()` at all — used to be a raw
// `include_str!` substring pin here; it is now the `no-contiguous` lint
// of `tools/pallas-audit` (run via `make audit`, required in CI), which
// checks the whole copy-free scope with a real parser instead of one
// file with a string match.

/// The `nn::Linear` packed-weight cache: one pack on the first forward,
/// zero weight copies/packs afterwards; an in-place weight update bumps
/// the storage version and triggers exactly one repack.
#[test]
fn linear_weight_packs_once_then_caches() {
    use nn::Module;
    let _guard = LINEAR_STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    torsk::rng::manual_seed(23);
    let layer = nn::Linear::new(33, 17);
    let x = Tensor::randn(&[5, 33]);

    let (h0, m0) = dispatch::packed_weight_stats();
    let y1 = torsk::autograd::no_grad(|| layer.forward(&x));
    let (h1, m1) = dispatch::packed_weight_stats();
    assert_eq!(m1 - m0, 1, "first forward must pack the weight exactly once");
    assert_eq!(h1 - h0, 0);

    let y2 = torsk::autograd::no_grad(|| layer.forward(&x));
    let (h2, m2) = dispatch::packed_weight_stats();
    assert_eq!(m2 - m1, 0, "second forward must not repack (zero weight copies)");
    assert_eq!(h2 - h1, 1, "second forward must hit the cache");
    assert_eq!(y1.to_vec::<f32>(), y2.to_vec::<f32>());

    // An in-place update (what an optimizer step does) invalidates.
    torsk::autograd::no_grad(|| layer.weight.mul_scalar_(0.5));
    let y3 = torsk::autograd::no_grad(|| layer.forward(&x));
    let (_, m3) = dispatch::packed_weight_stats();
    assert_eq!(m3 - m2, 1, "weight mutation must trigger exactly one repack");
    let half: Vec<f32> = y1
        .to_vec::<f32>()
        .iter()
        .zip(layer.bias.as_ref().unwrap().to_vec::<f32>().iter().cycle())
        .map(|(&y, &b)| (y - b) * 0.5 + b)
        .collect();
    torsk::tensor::assert_close(
        &y3,
        &Tensor::from_vec(half, y3.shape()),
        1e-5,
        1e-5,
    );
}

/// Degenerate alpha/beta/k combos — the explicit early-out table — exact
/// to the bit at the public API.
#[test]
fn degenerate_table_is_exact() {
    let c0 = vec![2.0f32, -3.0, 0.25, 8.0, -1.0, 4.0];
    for &k in &[0usize, 4] {
        for &alpha in &[0.0f32, 1.0] {
            if k != 0 && alpha != 0.0 {
                continue; // non-degenerate
            }
            for &beta in &[0.0f32, 1.0, 0.5] {
                let a = vec![9.0f32; 2 * k];
                let b = vec![9.0f32; k * 3];
                let mut c = c0.clone();
                sgemm(Trans::N, Trans::N, 2, 3, k, alpha, &a, &b, beta, &mut c);
                let expect: Vec<f32> = if beta == 0.0 {
                    vec![0.0; 6]
                } else if beta == 1.0 {
                    c0.clone()
                } else {
                    c0.iter().map(|&x| beta * x).collect()
                };
                assert_eq!(c, expect, "k={k} alpha={alpha} beta={beta}");
            }
        }
    }
}
