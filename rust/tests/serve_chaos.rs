//! Serving chaos suite: every injected serving fault must surface the
//! contracted way — a typed [`ServeError`] scoped to ONE request, a
//! server that keeps serving everyone else, and a shutdown that joins
//! **bounded** and names what it could not join. Never a silent drop,
//! never an unbounded hang.
//!
//! Faults injected here, via `testing::chaos::RequestFaults`
//! (request-scoped, instance-held — serve workers execute on their own
//! threads, where the thread-scoped registry could never fire):
//! - panic the handler on a chosen request **while it is co-batched**:
//!   the victim fails with [`ServeError::HandlerPanic`], its neighbours
//!   get their real outputs (poison isolation re-runs them alone);
//! - abandon a request (drop its `Pending` mid-flight): delivery
//!   becomes a no-op write, counted, and the batcher never wedges;
//! - wedge a worker forever: `shutdown` returns within its budget with
//!   the wedged request named by seq, and the straggler is detached.
//!
//! No test sleeps to "give threads time": stalls are condvar [`Gate`]s
//! the test controls, and the only timeouts exercised are the bounded
//! waits under test themselves.

use std::time::Duration;

use torsk::nn::{Linear, Module, ReLU, Sequential};
use torsk::serve::{serve_stats, ServeConfig, ServeError, Server};
use torsk::tensor::Tensor;
use torsk::testing::chaos::{Gate, RequestFaults};

const IN: usize = 8;
const OUT: usize = 4;

fn build_arch() -> Box<dyn Module> {
    Box::new(Sequential::new().add(Linear::new(IN, 16)).add(ReLU).add(Linear::new(16, OUT)))
}

fn input() -> Tensor {
    Tensor::ones(&[IN])
}

/// Stall request 0 so requests 1..=3 deterministically coalesce into one
/// batch; request 2 is armed to panic. The group run panics, poison
/// isolation re-runs the three alone: 1 and 3 are served, 2 fails with a
/// typed error naming it — and the server keeps serving afterwards.
#[test]
fn panicking_handler_fails_that_request_typed_while_neighbours_survive() {
    let faults = RequestFaults::new();
    let release = Gate::new();
    faults.stall_on(0, release.clone());
    faults.panic_on(2);
    let cfg = ServeConfig::new(&[IN])
        .with_max_batch(8)
        .with_max_delay(Duration::from_millis(100))
        .with_workers(1)
        .with_chaos(faults.clone());
    let server = Server::new(build_arch, cfg);
    let handle = server.handle();

    let p0 = handle.submit(input()).unwrap();
    faults.stalled().wait(); // worker provably wedged on request 0
    let p1 = handle.submit(input()).unwrap();
    let p2 = handle.submit(input()).unwrap();
    let p3 = handle.submit(input()).unwrap();
    assert_eq!((p1.seq(), p2.seq(), p3.seq()), (1, 2, 3));
    release.open();

    assert_eq!(p0.wait().expect("request 0 served").shape(), &[OUT]);
    assert_eq!(p1.wait().expect("innocent neighbour 1 served").shape(), &[OUT]);
    match p2.wait() {
        Err(ServeError::HandlerPanic { seq: 2, msg }) => {
            assert!(msg.contains("chaos[request 2]"), "panic payload rides along: {msg}");
        }
        other => panic!("request 2 must fail typed, got {other:?}"),
    }
    assert_eq!(p3.wait().expect("innocent neighbour 3 served").shape(), &[OUT]);

    // The server keeps serving after the panic.
    let p4 = handle.submit(input()).unwrap();
    assert_eq!(p4.wait().expect("served after panic").shape(), &[OUT]);

    let stats = server.stats();
    assert_eq!(stats.completed, 4, "{stats:?}");
    assert_eq!(stats.failed, 1, "{stats:?}");
    // Exactly two panicking executions: the {1,2,3} group, then 2 alone.
    assert_eq!(stats.handler_panics, 2, "{stats:?}");
    // Fault fired thrice: the stall, the group panic, the solo panic.
    assert_eq!(faults.hits(), 3);
    let report = server.shutdown();
    assert!(!report.timed_out, "{report}");
}

/// A client that walks away (drops its `Pending`) must not wedge
/// anything: the worker's delivery is a counted no-op and every other
/// request keeps flowing.
#[test]
fn abandoned_client_never_wedges_the_batcher() {
    let faults = RequestFaults::new();
    let release = Gate::new();
    faults.stall_on(0, release.clone());
    let cfg = ServeConfig::new(&[IN])
        .with_max_batch(4)
        .with_max_delay(Duration::from_millis(20))
        .with_workers(1)
        .with_chaos(faults.clone());
    let server = Server::new(build_arch, cfg);
    let handle = server.handle();

    let p0 = handle.submit(input()).unwrap();
    faults.stalled().wait();
    drop(p0); // abandon while the request is provably in flight
    release.open();

    // Everything after the abandonment is served normally.
    for _ in 0..3 {
        let p = handle.submit(input()).unwrap();
        assert_eq!(p.wait().expect("served past the abandonment").shape(), &[OUT]);
    }
    let stats = server.stats();
    assert_eq!(stats.abandoned, 1, "{stats:?}");
    assert_eq!(stats.completed, 4, "delivery into the void still completes: {stats:?}");
    let report = server.shutdown();
    assert!(!report.timed_out, "{report}");
}

/// Shutdown with a wedged worker: returns within the configured budget
/// (never an unbounded join) and the report names the wedged in-flight
/// request by seq and worker. The straggler is detached — and once the
/// test releases it, it still finishes its request and exits.
#[test]
fn shutdown_joins_bounded_and_names_the_wedged_request() {
    let faults = RequestFaults::new();
    let release = Gate::new();
    faults.stall_on(0, release.clone());
    let cfg = ServeConfig::new(&[IN])
        .with_max_batch(4)
        .with_max_delay(Duration::from_millis(10))
        .with_workers(1)
        .with_join_timeout(Duration::from_millis(200))
        .with_chaos(faults.clone());
    let server = Server::new(build_arch, cfg);
    let handle = server.handle();

    let p0 = handle.submit(input()).unwrap();
    faults.stalled().wait(); // wedged before shutdown begins — no race

    let report = server.shutdown();
    assert!(report.timed_out, "worker is wedged; the join must time out: {report}");
    assert_eq!(report.wedged.len(), 1, "{report}");
    assert_eq!(report.wedged[0].worker, 0);
    assert_eq!(report.wedged[0].seqs, vec![0], "the wedged request is named by seq");
    let text = format!("{report}");
    assert!(text.contains("worker 0") && text.contains("[0]"), "{text}");

    // New submissions are refused typed after shutdown.
    match handle.submit(input()) {
        Err(ServeError::Shutdown) => {}
        other => panic!("post-shutdown submit must fail typed, got {other:?}"),
    }

    // Release the detached worker: it finishes its request and exits.
    release.open();
    assert_eq!(p0.wait().expect("detached worker still answers").shape(), &[OUT]);
}

/// The reject paths are typed and counted: a wrong-shape tensor never
/// reaches the queue, and the process-global `serve_stats()` aggregate
/// observes this server's traffic.
#[test]
fn bad_shape_is_rejected_typed_and_global_stats_observe_traffic() {
    let global_before = serve_stats();
    let cfg = ServeConfig::new(&[IN]).with_max_delay(Duration::from_millis(5));
    let server = Server::new(build_arch, cfg);
    let handle = server.handle();

    match handle.submit(Tensor::ones(&[IN + 1])) {
        Err(ServeError::ShapeMismatch { expected, found }) => {
            assert_eq!(expected, vec![IN]);
            assert_eq!(found, vec![IN + 1]);
        }
        other => panic!("shape mismatch must be typed, got {other:?}"),
    }

    let p = handle.submit(input()).unwrap();
    assert_eq!(p.wait().expect("served").shape(), &[OUT]);

    let stats = server.stats();
    assert_eq!(stats.rejected, 1, "{stats:?}");
    assert_eq!(stats.completed, 1);
    let report = server.shutdown();
    assert!(!report.timed_out, "{report}");

    // Global counters are cumulative across servers (and concurrent
    // tests), so assert this test's contribution as a lower bound.
    let global_after = serve_stats();
    assert!(global_after.requests >= global_before.requests + 1);
    assert!(global_after.rejected >= global_before.rejected + 1);
    assert!(global_after.completed >= global_before.completed + 1);
}
