//! Property-based tests over core invariants (mini-harness in
//! `torsk::testing`; proptest is unavailable offline — DESIGN.md §7).

use torsk::alloc::{Allocator, StreamId};
use torsk::prelude::*;
use torsk::rng::Rng;
use torsk::testing::{for_all, gen_shape, gen_vec};

#[test]
fn prop_broadcast_add_commutes() {
    for_all(
        "a+b == b+a under broadcasting",
        40,
        |r| {
            let shape_a = gen_shape(r, 3, 5);
            // b broadcast-compatible: drop leading dims / set some to 1.
            let keep = r.below(shape_a.len() as u64 + 1) as usize;
            let mut shape_b: Vec<usize> = shape_a[shape_a.len() - keep..].to_vec();
            for d in shape_b.iter_mut() {
                if r.bernoulli(0.4) {
                    *d = 1;
                }
            }
            if shape_b.is_empty() {
                shape_b.push(1);
            }
            let na: usize = shape_a.iter().product();
            let nb: usize = shape_b.iter().product();
            (
                Tensor::from_vec(gen_vec(r, na, -5.0, 5.0), &shape_a),
                Tensor::from_vec(gen_vec(r, nb, -5.0, 5.0), &shape_b),
            )
        },
        |(a, b)| {
            let ab = ops::add(a, b).to_vec::<f32>();
            let ba = ops::add(b, a).to_vec::<f32>();
            ab == ba
        },
    );
}

#[test]
fn prop_sum_to_shape_preserves_total() {
    for_all(
        "sum_to_shape conserves mass",
        40,
        |r| {
            let shape = gen_shape(r, 4, 5);
            let n: usize = shape.iter().product();
            let t = Tensor::from_vec(gen_vec(r, n, -2.0, 2.0), &shape);
            let target: Vec<usize> =
                shape.iter().map(|&d| if r.bernoulli(0.5) { 1 } else { d }).collect();
            (t, target)
        },
        |(t, target)| {
            let reduced = ops::sum_to_shape(t, target);
            let a = ops::sum(t).item();
            let b = ops::sum(&reduced).item();
            (a - b).abs() <= 1e-3 + 1e-4 * a.abs()
        },
    );
}

#[test]
fn prop_autograd_is_linear_in_seed() {
    // backward(k * g) must produce k * backward(g) for any op chain.
    for_all(
        "vjp linearity",
        25,
        |r| {
            let n = 1 + r.below(20) as usize;
            (gen_vec(r, n, -2.0, 2.0), gen_vec(r, n, -1.0, 1.0), r.uniform_range(0.5, 3.0))
        },
        |(xs, gs, k)| {
            let run = |scale: f32| -> Vec<f32> {
                let x = Tensor::from_slice(xs).requires_grad(true);
                let y = ops::mul(&ops::tanh(&x), &ops::sigmoid(&x));
                let seed = Tensor::from_slice(gs).mul_scalar(scale);
                y.backward_with(seed);
                x.grad().unwrap().to_vec::<f32>()
            };
            let g1 = run(1.0);
            let gk = run(*k);
            g1.iter().zip(&gk).all(|(a, b)| (a * k - b).abs() <= 1e-4 + 1e-4 * b.abs())
        },
    );
}

#[test]
fn prop_matmul_distributes_over_addition() {
    for_all(
        "A(B+C) == AB + AC",
        25,
        |r| {
            let (m, k, n) = (
                1 + r.below(12) as usize,
                1 + r.below(12) as usize,
                1 + r.below(12) as usize,
            );
            (
                Tensor::from_vec(gen_vec(r, m * k, -1.0, 1.0), &[m, k]),
                Tensor::from_vec(gen_vec(r, k * n, -1.0, 1.0), &[k, n]),
                Tensor::from_vec(gen_vec(r, k * n, -1.0, 1.0), &[k, n]),
            )
        },
        |(a, b, c)| {
            let lhs = ops::matmul(a, &ops::add(b, c)).to_vec::<f32>();
            let rhs = ops::add(&ops::matmul(a, b), &ops::matmul(a, c)).to_vec::<f32>();
            lhs.iter().zip(&rhs).all(|(x, y)| (x - y).abs() <= 1e-3 + 1e-3 * y.abs())
        },
    );
}

#[test]
fn prop_allocator_blocks_never_overlap() {
    // Random alloc/free traces: live blocks must be disjoint and aligned,
    // sizes rounded to 512.
    for_all(
        "caching allocator no-overlap",
        15,
        |r| {
            let ops: Vec<(bool, usize)> = (0..120)
                .map(|_| (r.bernoulli(0.6), 1 + r.below(8192) as usize))
                .collect();
            ops
        },
        |trace| {
            let alloc = torsk::alloc::caching::CachingAllocator::new(std::sync::Arc::new(
                torsk::alloc::driver::HostMem::default(),
            ));
            let mut live: Vec<torsk::alloc::Block> = vec![];
            for &(is_alloc, size) in trace {
                if is_alloc || live.is_empty() {
                    let b = alloc.allocate(size, StreamId::DEFAULT);
                    assert_eq!(b.size % 512, 0);
                    assert!(b.size >= size);
                    live.push(b);
                } else {
                    let b = live.swap_remove(live.len() / 2);
                    alloc.deallocate(b);
                }
                // Check pairwise disjointness of live blocks.
                for i in 0..live.len() {
                    for j in i + 1..live.len() {
                        let (a, b) = (&live[i], &live[j]);
                        let (a0, a1) = (a.ptr.as_ptr() as usize, a.ptr.as_ptr() as usize + a.size);
                        let (b0, b1) = (b.ptr.as_ptr() as usize, b.ptr.as_ptr() as usize + b.size);
                        if a0 < b1 && b0 < a1 {
                            return false;
                        }
                    }
                }
            }
            for b in live {
                alloc.deallocate(b);
            }
            true
        },
    );
}

#[test]
fn prop_reshape_roundtrip_preserves_data() {
    for_all(
        "reshape roundtrip",
        30,
        |r| {
            let shape = gen_shape(r, 4, 6);
            let n: usize = shape.iter().product();
            (Tensor::from_vec(gen_vec(r, n, -9.0, 9.0), &shape), shape)
        },
        |(t, shape)| {
            let n = t.numel();
            let flat = t.reshape(&[n]);
            let back = flat.reshape(shape);
            back.to_vec::<f32>() == t.to_vec::<f32>()
        },
    );
}

#[test]
fn prop_softmax_rows_are_distributions() {
    for_all(
        "softmax simplex",
        30,
        |r| {
            let rows = 1 + r.below(10) as usize;
            let cols = 2 + r.below(20) as usize;
            Tensor::from_vec(gen_vec(r, rows * cols, -20.0, 20.0), &[rows, cols])
        },
        |t| {
            let s = ops::softmax_last(t);
            let v = s.to_vec::<f32>();
            let cols = t.size(1);
            v.iter().all(|&p| (0.0..=1.0).contains(&p))
                && v.chunks(cols).all(|row| (row.iter().sum::<f32>() - 1.0).abs() < 1e-4)
        },
    );
}

#[test]
fn prop_stream_results_match_host() {
    // Any elementwise chain computed on the stream device equals the host
    // result (stream FIFO + per-stream pools are sound).
    for_all(
        "sim == cpu",
        20,
        |r| {
            let n = 1 + r.below(300) as usize;
            (gen_vec(r, n, -3.0, 3.0), gen_vec(r, n, 0.1, 2.0))
        },
        |(a, b)| {
            let compute = |dev: torsk::device::Device| {
                let x = Tensor::from_slice(a).to_device(dev);
                let y = Tensor::from_slice(b).to_device(dev);
                let z = ops::mul(&ops::tanh(&ops::add(&x, &y)), &ops::sqrt(&y));
                z.to_vec::<f32>()
            };
            let h = compute(torsk::device::Device::Cpu);
            let d = compute(torsk::device::Device::Sim);
            h.iter().zip(&d).all(|(x, y)| (x - y).abs() < 1e-6)
        },
    );
}

#[test]
fn prop_gradcheck_random_unary_chains() {
    // Finite-difference gradcheck over random compositions of smooth ops.
    for_all(
        "gradcheck",
        12,
        |r| {
            let n = 2 + r.below(6) as usize;
            let chain: Vec<u64> = (0..3).map(|_| r.below(4)).collect();
            (gen_vec(r, n, 0.2, 1.5), chain)
        },
        |(xs, chain)| {
            let apply = |t: &Tensor| -> Tensor {
                let mut y = t.clone();
                for &c in chain {
                    y = match c {
                        0 => ops::tanh(&y),
                        1 => ops::sigmoid(&y),
                        2 => ops::exp(&ops::mul_scalar(&y, 0.3)),
                        _ => ops::sqrt(&ops::add_scalar(&y, 2.0)),
                    };
                }
                y
            };
            let x = Tensor::from_slice(xs).requires_grad(true);
            ops::sum(&apply(&x)).backward();
            let grad = x.grad().unwrap().to_vec::<f32>();
            let eps = 1e-3f32;
            let mut r2 = Rng::new(5);
            let idx = r2.below(xs.len() as u64) as usize;
            let mut xp = xs.clone();
            xp[idx] += eps;
            let mut xm = xs.clone();
            xm[idx] -= eps;
            let fp = ops::sum(&apply(&Tensor::from_slice(&xp))).item();
            let fm = ops::sum(&apply(&Tensor::from_slice(&xm))).item();
            let fd = (fp - fm) / (2.0 * eps);
            (grad[idx] - fd).abs() <= 2e-2 + 1e-2 * fd.abs()
        },
    );
}
