//! OpInfo-driven coverage: every op in the dispatch registry is
//! exercised through its own `sample_inputs` generator — a smoke call per
//! (dtype, seed) plus a central-difference numeric gradcheck of every
//! declared differentiable input, at F32 and F64.
//!
//! This is the TorchBench lesson (API-surface coverage ⇒ correctness
//! confidence) made structural: `Registry::add` refuses sample-less ops,
//! so a new op cannot merge without landing in this suite. Failures name
//! the op, dtype, seed, input and element so any case replays directly.

use torsk::autograd::no_grad;
use torsk::dispatch::{self, OpSample};
use torsk::ops;
use torsk::tensor::{to_f64_vec, DType};
use torsk::Tensor;

const SEEDS: [u64; 2] = [0, 1];
const DTYPES: [DType; 2] = [DType::F32, DType::F64];

fn call_op(name: &str, inputs: &[Tensor], params: &[dispatch::Param]) -> Tensor {
    let refs: Vec<&Tensor> = inputs.iter().collect();
    dispatch::call(name, &refs, params)
}

/// Scalarize an output with fixed pseudo-random weights so every output
/// element contributes to the checked gradient.
fn weights_for(seed: u64, out: &Tensor) -> Tensor {
    dispatch::sample_uniform(seed ^ 0x7777, out.shape(), out.dtype(), 0.5, 1.5)
        .expect("differentiable ops produce float outputs")
}

fn loss_of(out: &Tensor, w: &Tensor) -> f64 {
    to_f64_vec(&ops::sum(&ops::mul(out, w)))[0]
}

/// Clone `t` with element `j` shifted by `delta`; returns the tensor and
/// the *achieved* shift (f32 rounding makes x+eps-x differ from eps).
fn perturb(t: &Tensor, j: usize, delta: f64) -> (Tensor, f64) {
    match t.dtype() {
        DType::F32 => {
            let mut v = t.to_vec::<f32>();
            let old = v[j];
            v[j] = old + delta as f32;
            let achieved = v[j] as f64 - old as f64;
            (Tensor::from_vec(v, t.shape()), achieved)
        }
        DType::F64 => {
            let mut v = t.to_vec::<f64>();
            let old = v[j];
            v[j] = old + delta;
            let achieved = v[j] - old;
            (Tensor::from_vec(v, t.shape()), achieved)
        }
        DType::I64 => unreachable!("gradcheck inputs are float"),
    }
}

fn eval_perturbed(
    name: &str,
    sample: &OpSample,
    gi: usize,
    j: usize,
    delta: f64,
    w: &Tensor,
) -> (f64, f64) {
    no_grad(|| {
        let mut inputs: Vec<Tensor> = sample.inputs.iter().map(|t| t.detach()).collect();
        let (t, achieved) = perturb(&sample.inputs[gi], j, delta);
        inputs[gi] = t;
        (loss_of(&call_op(name, &inputs, &sample.params), w), achieved)
    })
}

/// Numeric gradcheck of `sample.grad_inputs` against autograd.
fn gradcheck(name: &str, sample: &OpSample, dt: DType, seed: u64) {
    let leaves: Vec<Tensor> = sample
        .inputs
        .iter()
        .enumerate()
        .map(|(i, t)| {
            if sample.grad_inputs.contains(&i) {
                t.detach().requires_grad(true)
            } else {
                t.detach()
            }
        })
        .collect();
    let refs: Vec<&Tensor> = leaves.iter().collect();
    let out = dispatch::call(name, &refs, &sample.params);
    let w = weights_for(seed, &out);
    let loss = ops::sum(&ops::mul(&out, &w));
    loss.backward();

    let (eps, atol, rtol) = match dt {
        DType::F32 => (1e-2, 2e-2, 6e-2),
        _ => (1e-5, 1e-6, 1e-5),
    };

    for &gi in &sample.grad_inputs {
        let g = leaves[gi].grad().unwrap_or_else(|| {
            panic!("op `{name}` (dtype {dt}, seed {seed}): no gradient reached input {gi}")
        });
        assert_eq!(
            g.shape(),
            sample.inputs[gi].shape(),
            "op `{name}` (dtype {dt}, seed {seed}): grad shape mismatch on input {gi}"
        );
        let gv = to_f64_vec(&g);
        let n = sample.inputs[gi].numel();
        for j in 0..n {
            let (lp, dp) = eval_perturbed(name, sample, gi, j, eps, &w);
            let (lm, dm) = eval_perturbed(name, sample, gi, j, -eps, &w);
            let fd = (lp - lm) / (dp - dm);
            let tol = atol + rtol * fd.abs();
            assert!(
                (gv[j] - fd).abs() <= tol,
                "OpInfo gradcheck failed for op `{name}` (dtype {dt}, seed {seed}): \
                 input {gi}, element {j}: autograd {} vs finite-diff {fd} (tol {tol})",
                gv[j]
            );
        }
    }
}

#[test]
fn every_registered_op_passes_opinfo_gradcheck() {
    let mut smoke_calls = 0usize;
    let mut gradchecked_ops = 0usize;
    for name in dispatch::op_names() {
        let info = dispatch::op_info(name).expect("registered op has OpInfo");
        let mut op_had_sample = false;
        let mut op_gradchecked = false;
        for dt in DTYPES {
            for seed in SEEDS {
                let Some(sample) = (info.sample)(seed, dt) else { continue };
                op_had_sample = true;
                assert!(
                    sample.inputs.len() >= info.min_inputs
                        && sample.inputs.len() <= info.max_inputs,
                    "op `{name}`: sample arity {} outside schema {}..={}",
                    sample.inputs.len(),
                    info.min_inputs,
                    info.max_inputs
                );
                // Smoke: every op must run its sample without panicking,
                // and float outputs must be finite.
                let out = no_grad(|| call_op(name, &sample.inputs, &sample.params));
                if out.dtype().is_float() {
                    for (i, v) in to_f64_vec(&out).iter().enumerate() {
                        assert!(
                            v.is_finite(),
                            "op `{name}` (dtype {dt}, seed {seed}): non-finite output at {i}: {v}"
                        );
                    }
                }
                smoke_calls += 1;
                if !sample.grad_inputs.is_empty() {
                    // Fresh sample: the smoke call may have mutated the
                    // first one (in-place ops, running stats).
                    let sample = (info.sample)(seed, dt).expect("sample is reproducible");
                    gradcheck(name, &sample, dt, seed);
                    op_gradchecked = true;
                }
            }
        }
        assert!(op_had_sample, "op `{name}` produced no sample at any dtype");
        if op_gradchecked {
            gradchecked_ops += 1;
        }
    }
    assert!(smoke_calls >= 60, "suspiciously few OpInfo smoke calls: {smoke_calls}");
    assert!(
        gradchecked_ops >= 30,
        "suspiciously few gradchecked ops: {gradchecked_ops} — did samples lose grad_inputs?"
    );
}

#[test]
fn opinfo_failure_message_names_op_and_seed() {
    // The contract the suite's diagnostics promise: a failing gradcheck
    // panics with the op name and sample seed embedded.
    let sample = OpSample {
        inputs: vec![Tensor::from_slice(&[0.5f32, -0.25])],
        params: vec![],
        grad_inputs: vec![0],
    };
    // relu's sample is valid, so gradcheck passes...
    gradcheck("relu", &sample, DType::F32, 7);
    // ...and a sabotaged comparison panics with the replay coordinates.
    let r = std::panic::catch_unwind(|| {
        let bad = OpSample {
            // A kink point: FD straddles relu's corner, so the check fails.
            inputs: vec![Tensor::from_slice(&[0.0f32, 0.001])],
            params: vec![],
            grad_inputs: vec![0],
        };
        gradcheck("relu", &bad, DType::F32, 9);
    });
    let msg = match r {
        Ok(()) => panic!("kink-point gradcheck unexpectedly passed"),
        Err(e) => e
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".to_string()),
    };
    assert!(msg.contains("`relu`") && msg.contains("seed 9"), "diagnostics missing: {msg}");
}
