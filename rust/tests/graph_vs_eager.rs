//! Cross-validation of the three layers: AOT XLA graphs (L2+L1, via PJRT)
//! against the Rust eager engine (L3) on identical inputs.
//!
//! These tests need `make artifacts`; they skip (with a notice) if the
//! manifest is missing so `cargo test` works on a fresh checkout.

use torsk::graph::GraphTrainer;
use torsk::prelude::*;
use torsk::runtime::Runtime;

fn artifacts_available() -> bool {
    let ok = Runtime::global().list().map(|l| !l.is_empty()).unwrap_or(false);
    if !ok {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
    }
    ok
}

/// Rust-eager twin of python/compile/model.py::mlp_step (lr fused = 0.1).
fn eager_mlp_step(x: &Tensor, y: &Tensor, params: &[Tensor]) -> (f32, Vec<Tensor>) {
    let leaves: Vec<Tensor> =
        params.iter().map(|p| p.detach().contiguous().requires_grad(true)).collect();
    let h = ops::relu(&ops::linear(x, &leaves[0], Some(&leaves[1])));
    let logits = ops::linear(&h, &leaves[2], Some(&leaves[3]));
    let loss = ops::cross_entropy(&logits, y);
    loss.backward();
    let updated = leaves
        .iter()
        .map(|p| {
            let g = p.grad().expect("grad");
            no_grad(|| ops::add(&p.detach(), &ops::mul_scalar(&g, -0.1)))
        })
        .collect();
    (loss.item(), updated)
}

#[test]
fn mlp_graph_matches_eager_step_exactly() {
    if !artifacts_available() {
        return;
    }
    torsk::rng::manual_seed(11);
    let x = Tensor::randn(&[8, 16]);
    let y = Tensor::randint(4, &[8]);
    let g = Runtime::global().load("mlp_step").expect("mlp_step artifact");
    let params: Vec<Tensor> = g.meta.inputs[2..]
        .iter()
        .map(|s| Tensor::randn(&s.shape).mul_scalar(0.2))
        .collect();

    // Graph path (XLA, AOT, Pallas kernels inside).
    let mut inputs = vec![x.clone(), y.clone()];
    inputs.extend(params.iter().cloned());
    let out = g.run(&inputs).expect("run graph");
    let graph_loss = out[0].item();
    let graph_params = &out[1..];

    // Eager path (torsk kernels).
    let (eager_loss, eager_params) = eager_mlp_step(&x, &y, &params);

    assert!(
        (graph_loss - eager_loss).abs() < 1e-4,
        "loss: graph {graph_loss} vs eager {eager_loss}"
    );
    for (i, (gp, ep)) in graph_params.iter().zip(eager_params.iter()).enumerate() {
        assert_close(gp, ep, 1e-4, 1e-4);
        let _ = i;
    }
}

#[test]
fn graph_trainer_loss_decreases_over_steps() {
    if !artifacts_available() {
        return;
    }
    torsk::rng::manual_seed(13);
    let g = Runtime::global().load("mlp_step").unwrap();
    let init: Vec<Tensor> =
        g.meta.inputs[2..].iter().map(|s| Tensor::randn(&s.shape).mul_scalar(0.2)).collect();
    let mut trainer = GraphTrainer::new("mlp_step", 2, &init).unwrap();

    // Fixed batch: loss must drop monotonically-ish under repeated steps.
    let x = Tensor::randn(&[8, 16]);
    let y = Tensor::randint(4, &[8]);
    let mut losses = vec![];
    for _ in 0..20 {
        losses.push(trainer.step(&[x.clone(), y.clone()]).unwrap());
    }
    assert!(losses[19] < losses[0] * 0.5, "graph training: {losses:?}");
    assert_eq!(trainer.steps_run, 20);
    // State stayed on device; downloading it matches the input specs.
    let state = trainer.state_tensors().unwrap();
    assert_eq!(state.len(), init.len());
    for (s, i) in state.iter().zip(init.iter()) {
        assert_eq!(s.shape(), i.shape());
    }
}

#[test]
fn conv_block_artifact_matches_rust_conv() {
    // The Pallas im2col+matmul conv (L1) vs the Rust native conv kernel
    // (L3) — two independent implementations of the paper's conv path.
    if !artifacts_available() {
        return;
    }
    torsk::rng::manual_seed(17);
    let x = Tensor::randn(&[4, 8, 16, 16]);
    let w = Tensor::randn(&[16, 8, 3, 3]).mul_scalar(0.2);
    let b = Tensor::randn(&[16]).mul_scalar(0.1);

    let g = Runtime::global().load("conv_block").unwrap();
    let pallas_out = &g.run(&[x.clone(), w.clone(), b.clone()]).unwrap()[0];

    let rust_out = ops::relu(&ops::conv2d(&x, &w, Some(&b), 1, 1, 1));
    assert_close(pallas_out, &rust_out, 1e-3, 1e-3);
}

#[test]
fn all_manifest_graphs_compile() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::global();
    for name in rt.list().unwrap() {
        let g = rt.load(&name).unwrap_or_else(|e| panic!("compile {name}: {e}"));
        assert!(g.num_outputs() >= 1);
        assert!(!g.meta.inputs.is_empty(), "{name} has inputs");
    }
}

#[test]
fn table1_graph_artifacts_run_one_step() {
    if !artifacts_available() {
        return;
    }
    torsk::rng::manual_seed(19);
    // Each Table 1 train-step graph executes with random state and returns
    // a finite loss. (Throughput comparisons live in the bench.)
    for name in ["alexnet_step", "ncf_step"] {
        let g = Runtime::global().load(name).unwrap();
        let inputs: Vec<Tensor> = g
            .meta
            .inputs
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => Tensor::randn(&s.shape).mul_scalar(0.1),
                DType::F64 => Tensor::randn(&s.shape).mul_scalar(0.1).to_dtype(DType::F64),
                DType::I64 => {
                    // Tokens/labels: keep small so they're valid indices.
                    Tensor::randint(4, &s.shape)
                }
            })
            .collect();
        let out = g.run(&inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        let loss = out[0].item();
        assert!(loss.is_finite(), "{name} loss {loss}");
    }
}
