//! End-to-end integration tests: training convergence, device paths,
//! data loading, the full eager stack composing.

use std::sync::Arc;

use torsk::data::{DataLoader, Dataset, SyntheticImages};
use torsk::device::Device;
use torsk::models::{BenchModel, Batch};
use torsk::nn::{Linear, Module, ReLU, Sequential, Sigmoid};
use torsk::optim::{Adam, Optimizer, Sgd};
use torsk::prelude::*;

#[test]
fn xor_trains_to_high_accuracy() {
    torsk::rng::manual_seed(1);
    let model = Sequential::new()
        .add(Linear::new(2, 8))
        .add(ReLU)
        .add(Linear::new(8, 1))
        .add(Sigmoid);
    let x = Tensor::from_vec(vec![0.0f32, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0], &[4, 2]);
    let y = Tensor::from_vec(vec![0.0f32, 1.0, 1.0, 0.0], &[4, 1]);
    let mut opt = Adam::new(model.parameters(), 0.05);
    let mut final_loss = f32::MAX;
    for _ in 0..500 {
        opt.zero_grad();
        let loss = ops::bce_loss(&model.forward(&x), &y);
        loss.backward();
        opt.step();
        final_loss = loss.item();
    }
    assert!(final_loss < 0.05, "XOR should be solvable: loss={final_loss}");
    let pred = no_grad(|| model.forward(&x)).to_vec::<f32>();
    assert!(pred[0] < 0.2 && pred[3] < 0.2);
    assert!(pred[1] > 0.8 && pred[2] > 0.8);
}

#[test]
fn linear_regression_recovers_weights() {
    torsk::rng::manual_seed(2);
    let true_w = Tensor::from_slice(&[2.0f32, -3.0, 0.5]);
    let w = Tensor::zeros(&[3, 1]).requires_grad(true);
    let b = Tensor::zeros(&[1]).requires_grad(true);
    let mut opt = Sgd::new(vec![w.clone(), b.clone()], 0.1);
    for _ in 0..300 {
        opt.zero_grad();
        let x = Tensor::randn(&[32, 3]);
        let target = ops::add_scalar(&ops::matmul(&x, &true_w.reshape(&[3, 1])), 0.7);
        let pred = ops::add(&ops::matmul(&x, &w), &b);
        ops::mse_loss(&pred, &target).backward();
        opt.step();
    }
    let wv = w.to_vec::<f32>();
    for (got, want) in wv.iter().zip([2.0, -3.0, 0.5]) {
        assert!((got - want).abs() < 0.05, "{wv:?}");
    }
    assert!((b.item() - 0.7).abs() < 0.05);
}

#[test]
fn conv_classifier_learns_planted_signal() {
    torsk::rng::manual_seed(3);
    struct Planted;
    impl Dataset for Planted {
        fn len(&self) -> usize {
            128
        }
        fn get(&self, i: usize) -> (Tensor, Tensor) {
            let base = SyntheticImages::new(128, 1, 8, 8, 2);
            let (x, _) = base.get(i);
            let label = (i % 2) as i64;
            let mut v = x.to_vec::<f32>();
            if label == 1 {
                for p in v.iter_mut().take(16) {
                    *p += 3.0;
                }
            }
            (Tensor::from_vec(v, &[1, 8, 8]), Tensor::from_vec(vec![label], &[]))
        }
    }
    let model = Sequential::new()
        .add(torsk::nn::Conv2d::new(1, 4, 3, 1, 1))
        .add(ReLU)
        .add(torsk::nn::MaxPool2d::new(2, 2))
        .add(torsk::nn::Flatten)
        .add(Linear::new(4 * 16, 2));
    let loader = DataLoader::new(Arc::new(Planted), 16).shuffle(true).seed(5);
    let mut opt = Sgd::new(model.parameters(), 0.05).with_momentum(0.9);
    for _epoch in 0..5 {
        for (x, y) in loader.iter() {
            opt.zero_grad();
            model.forward(&x).cross_entropy(&y).backward();
            opt.step();
        }
    }
    // Evaluate.
    let mut correct = 0;
    no_grad(|| {
        for (x, y) in DataLoader::new(Arc::new(Planted), 32).iter() {
            let acc = ops::accuracy(&model.forward(&x), &y);
            correct += (acc * x.size(0) as f32) as usize;
        }
    });
    assert!(correct >= 120, "planted conv task: {correct}/128 correct");
}

#[test]
fn training_on_sim_device_matches_cpu() {
    // Same seed, same data: the simulated accelerator must produce the
    // same numbers as the host (it runs the same kernels, §5.2).
    let run = |device: Device| -> Vec<f32> {
        torsk::rng::manual_seed(7);
        let model = torsk::device::with_default_device(device, || {
            Sequential::new().add(Linear::new(4, 8)).add(ReLU).add(Linear::new(8, 3))
        });
        let mut opt = Sgd::new(model.parameters(), 0.1);
        torsk::rng::manual_seed(100);
        let x = Tensor::randn(&[16, 4]).to_device(device);
        let y = Tensor::randint(3, &[16]).to_device(device);
        let mut losses = vec![];
        for _ in 0..5 {
            opt.zero_grad();
            let loss = model.forward(&x).cross_entropy(&y);
            losses.push(loss.item());
            loss.backward();
            opt.step();
        }
        torsk::device::synchronize();
        losses
    };
    let cpu = run(Device::Cpu);
    let sim = run(Device::Sim);
    for (a, b) in cpu.iter().zip(sim.iter()) {
        assert!((a - b).abs() < 1e-4, "cpu {cpu:?} vs sim {sim:?}");
    }
    assert!(cpu[4] < cpu[0], "loss should decrease: {cpu:?}");
}

#[test]
fn bench_models_take_one_full_step() {
    // Tiny variants of every Table 1 model run forward+backward+update.
    torsk::rng::manual_seed(0);
    let models: Vec<Box<dyn BenchModel>> = vec![
        Box::new(torsk::models::AlexNet::new(3, 32, 10, 2)),
        Box::new(torsk::models::Vgg19::new(3, 32, 10, 1)),
        Box::new(torsk::models::ResNet50::new(3, 32, 10, 1)),
        Box::new(torsk::models::MobileNetV1::new(3, 32, 10, 1)),
        Box::new(torsk::models::Gnmt::new(64, 16, 1, 2, 4, 4)),
        Box::new(torsk::models::Ncf::new(64, 64, 8, 16)),
    ];
    for m in models {
        let mut opt = Sgd::new(m.parameters(), 0.01);
        let b = m.make_batch(0);
        let l0 = m.loss(&b);
        assert!(l0.item().is_finite(), "{} loss finite", m.name());
        l0.backward();
        opt.step();
        let l1 = m.loss(&b);
        assert!(l1.item().is_finite());
    }
}

#[test]
fn parallel_dataloader_feeds_training() {
    torsk::rng::manual_seed(4);
    let ds = Arc::new(SyntheticImages::new(64, 1, 4, 4, 3));
    let loader = DataLoader::new(ds, 8).workers(3).shuffle(true);
    let model = Sequential::new().add(torsk::nn::Flatten).add(Linear::new(16, 3));
    let mut opt = Sgd::new(model.parameters(), 0.01);
    let mut batches = 0;
    for (x, y) in loader.iter() {
        opt.zero_grad();
        model.forward(&x).cross_entropy(&y).backward();
        opt.step();
        batches += 1;
    }
    assert_eq!(batches, 8);
}

#[test]
fn gnmt_batch_units_are_tokens() {
    torsk::rng::manual_seed(0);
    let m = torsk::models::Gnmt::new(64, 16, 1, 4, 6, 5);
    match m.make_batch(0) {
        Batch::Seq2Seq(src, tgt) => {
            assert_eq!(src.shape(), &[4, 6]);
            assert_eq!(tgt.shape(), &[4, 5]);
        }
        _ => panic!("wrong batch type"),
    }
    assert_eq!(m.make_batch(0).units(), 20);
}

#[test]
fn memory_is_reclaimed_across_training_steps() {
    // §5.5: steady-state training must not grow memory (refcounting frees
    // every intermediate as soon as it is unreachable).
    use torsk::alloc::Allocator;
    torsk::rng::manual_seed(6);
    let model = Sequential::new().add(Linear::new(32, 64)).add(ReLU).add(Linear::new(64, 8));
    let mut opt = Sgd::new(model.parameters(), 0.01);
    let alloc = torsk::ctx::host_allocator();
    let mut in_use = vec![];
    for step in 0..6 {
        opt.zero_grad();
        let x = Tensor::randn(&[16, 32]);
        let y = Tensor::randint(8, &[16]);
        model.forward(&x).cross_entropy(&y).backward();
        opt.step();
        let _ = step;
        in_use.push(alloc.stats().in_use_bytes);
    }
    // After warmup the footprint must be flat.
    assert_eq!(in_use[3], in_use[4], "{in_use:?}");
    assert_eq!(in_use[4], in_use[5], "{in_use:?}");
}
