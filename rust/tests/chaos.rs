//! Chaos suite: every injected fault must end in **verified recovery**
//! (a resumed run reproduces the uninterrupted one bitwise) or a **loud,
//! typed failure** with state intact (a panic or `TorskError`, never a
//! silently truncated epoch, never a partial checkpoint file).
//!
//! Faults injected here, via `torsk::testing::chaos`:
//! - kill a training run mid-epoch and resume from its checkpoint;
//! - panic inside `Dataset::get` on a loader worker thread;
//! - panic inside `Collate`;
//! - wedge a worker forever inside `Dataset::get` (bounded drop-join);
//! - fail a checkpoint write after N bytes (torn write);
//! - corrupt a checkpoint on disk;
//! - SIGKILL a forked hogwild worker mid-run (typed per-rank diagnostics
//!   from `fork_workers`, surviving ranks' shared state intact).
//!
//! No test sleeps to "give threads time": stalls are condvar [`Gate`]s
//! the test controls, and recovery is asserted by bitwise comparison.
//! (The SIGKILL test polls for the victim's pid file — the victim is a
//! separate *process*, so no in-process gate can cross — but the poll is
//! deadline-bounded and its outcome is asserted, never assumed.)

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use torsk::data::{DataLoader, Dataset};
use torsk::nn::{Linear, Module, ReLU, Sequential};
use torsk::optim::{Adam, Optimizer};
use torsk::rng::Rng;
use torsk::serialize::{Checkpoint, LoaderState, FAULT_WRITE};
use torsk::tensor::Tensor;
use torsk::testing::chaos::{self, ChaosDataset, Gate, PanickingCollate};
use torsk::TorskError;

/// Serializes the tests that call `manual_seed` (the seed epoch is
/// process-global, and tests in one binary run concurrently).
static SERIAL: Mutex<()> = Mutex::new(());

static NEXT_FILE: AtomicUsize = AtomicUsize::new(0);

fn scratch(tag: &str) -> PathBuf {
    let n = NEXT_FILE.fetch_add(1, Ordering::SeqCst);
    std::env::temp_dir().join(format!("torsk-chaos-{}-{n}-{tag}.ckpt", std::process::id()))
}

const IN: usize = 8;
const OUT: usize = 4;
const N: usize = 64;
const BATCH: usize = 8; // 8 batches per epoch

/// Regression pairs, deterministic per index (`Rng::for_index`), so any
/// worker can fetch any sample and the bytes never depend on scheduling.
struct Synth;

impl Dataset for Synth {
    fn len(&self) -> usize {
        N
    }

    fn get(&self, index: usize) -> (Tensor, Tensor) {
        let mut r = Rng::for_index(0xDA7A, index as u64);
        let x: Vec<f32> = (0..IN).map(|_| r.normal()).collect();
        let y: Vec<f32> = (0..OUT).map(|_| r.normal()).collect();
        (Tensor::from_vec(x, &[IN]), Tensor::from_vec(y, &[OUT]))
    }
}

fn fresh_model_and_opt(init_seed: u64) -> (Sequential, Adam) {
    torsk::rng::manual_seed(init_seed);
    let model = Sequential::new().add(Linear::new(IN, 16)).add(ReLU).add(Linear::new(16, OUT));
    let opt = Adam::new(model.parameters(), 1e-2);
    (model, opt)
}

fn loader(workers: usize) -> DataLoader {
    DataLoader::new(Arc::new(Synth), BATCH).shuffle(true).seed(11).workers(workers)
}

fn train_step(model: &Sequential, opt: &mut Adam, x: &Tensor, y: &Tensor) {
    opt.zero_grad();
    let loss = model.forward(x).mse_loss(y);
    loss.backward();
    opt.step();
}

/// All model parameters as exact bit patterns.
fn param_bits(model: &Sequential) -> Vec<u32> {
    model
        .state_dict()
        .values()
        .flat_map(|t| t.to_vec::<f32>().into_iter().map(f32::to_bits))
        .collect()
}

/// Kill-and-resume determinism, the tentpole pin: a run checkpointed at
/// (epoch 1, batch 4), killed mid-epoch (iterator dropped, workers wound
/// down), and resumed from disk in a "fresh process" (new model, new
/// optimizer, new loader) must finish with parameters **bitwise equal**
/// to an uninterrupted 3-epoch run. Exercised serial and parallel; CI
/// re-runs this suite across `PALLAS_NUM_THREADS` 1/2/8.
#[test]
fn kill_and_resume_matches_uninterrupted_run_bitwise() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    for workers in [0, 4] {
        // Uninterrupted reference: 3 full epochs.
        let (model, mut opt) = fresh_model_and_opt(42);
        let dl = loader(workers);
        for _ in 0..3 {
            for (x, y) in dl.iter() {
                train_step(&model, &mut opt, &x, &y);
            }
        }
        let expected = param_bits(&model);

        // Interrupted run, same init: epoch 0 in full, then 4 batches of
        // epoch 1, checkpoint, and a mid-epoch kill.
        let path = scratch(&format!("resume-w{workers}"));
        let (model, mut opt) = fresh_model_and_opt(42);
        let dl = loader(workers);
        for (x, y) in dl.iter() {
            train_step(&model, &mut opt, &x, &y);
        }
        {
            let mut epoch1 = dl.iter();
            for _ in 0..4 {
                let (x, y) = epoch1.next().expect("epoch has 8 batches");
                train_step(&model, &mut opt, &x, &y);
            }
            Checkpoint::new(model.state_dict())
                .with_optimizer(&opt)
                .with_loader(LoaderState { seed: dl.seed_value(), epoch: 1, next_batch: 4 })
                .save(&path)
                .unwrap();
            // Kill: the epoch-1 iterator dies here with 4 batches unread;
            // its workers are shut down and joined by the drop.
        }
        drop((model, opt, dl));

        // "New process": rebuild everything with a *different* init so
        // only the checkpoint can explain a bitwise match.
        let (model, mut opt) = fresh_model_and_opt(999);
        let ck = Checkpoint::load(&path).unwrap();
        model.load_state_dict(&ck.model);
        opt.load_state_dict(ck.optim.as_ref().unwrap());
        let ls = ck.loader.unwrap();
        let dl = loader(workers);
        assert_eq!(ls.seed, dl.seed_value(), "loader must be rebuilt with the saved seed");
        dl.resume(ls.epoch as usize, ls.next_batch as usize);
        for (x, y) in dl.iter() {
            // The remaining 4 batches of epoch 1.
            train_step(&model, &mut opt, &x, &y);
        }
        for (x, y) in dl.iter() {
            // Epoch 2.
            train_step(&model, &mut opt, &x, &y);
        }
        assert_eq!(
            param_bits(&model),
            expected,
            "resumed run (workers={workers}) must be bitwise identical"
        );
        std::fs::remove_file(&path).unwrap();
    }
}

/// A worker killed by a panicking `Dataset::get` must not truncate the
/// epoch: the consumer detects the missing batch and re-panics loudly on
/// the training thread.
#[test]
#[should_panic(expected = "DataLoader worker thread panicked")]
fn worker_death_mid_epoch_fails_loudly() {
    let ds = ChaosDataset::new(Arc::new(Synth)).panic_at(21);
    let dl = DataLoader::new(Arc::new(ds), BATCH).workers(2);
    let n = dl.iter().count(); // must not complete silently
    panic!("epoch silently yielded {n} batches past a dead worker");
}

/// Same contract when the panic is in `Collate` rather than the dataset.
#[test]
#[should_panic(expected = "DataLoader worker thread panicked")]
fn collate_panic_mid_epoch_fails_loudly() {
    let dl = DataLoader::new(Arc::new(Synth), BATCH)
        .collate(Arc::new(PanickingCollate::new(3)))
        .workers(2);
    let n = dl.iter().count();
    panic!("epoch silently yielded {n} batches past a dead collate");
}

/// At `workers = 0` the same collate bug panics in-line — the contract
/// (loud failure, identical at any worker count) holds trivially.
#[test]
#[should_panic(expected = "chaos: collate panic injected")]
fn collate_panic_is_equally_loud_in_serial_mode() {
    let dl = DataLoader::new(Arc::new(Synth), BATCH).collate(Arc::new(PanickingCollate::new(3)));
    let _ = dl.iter().count();
}

/// A worker wedged forever inside `Dataset::get` must not hang the
/// training thread's `drop`: the bounded join times out, names the stuck
/// worker and its last claimed batch, and detaches.
#[test]
fn wedged_worker_is_named_and_detached_on_drop() {
    let release = Gate::new();
    // Batch 3 holds indices 12..16 (sequential sampler): the worker that
    // claims batch 3 blocks inside get(12) until `release` opens.
    let ds = Arc::new(ChaosDataset::new(Arc::new(Synth)).stall_at(12, release.clone()));
    let stalled = ds.stalled();
    let dl = DataLoader::new(ds, 4).workers(2).join_timeout_ms(100);
    let before = dl.stats();
    let it = dl.iter();
    // Provably wedged — the stalled gate opens from inside get(12) — so
    // the drop below *must* take the timeout path; no timing assumptions.
    stalled.wait();
    drop(it);
    let d = dl.stats().delta(&before);
    assert_eq!(d.join_timeouts, 1, "drop must record the timed-out join");
    let msg = dl.last_join_timeout().expect("diagnostic recorded");
    assert!(msg.contains("torsk-data-"), "must name the stuck worker thread: {msg}");
    assert!(msg.contains("last claimed batch 3"), "must name the wedged batch: {msg}");
    // Release the detached thread so it exits cleanly (its send fails on
    // the disconnected queue and it returns).
    release.open();
}

/// A save that dies mid-write (disk full, kill -9) must surface a typed
/// I/O error and leave the previous checkpoint byte-for-byte intact, with
/// no partial or temp files.
#[test]
fn torn_checkpoint_write_keeps_the_previous_checkpoint() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let path = scratch("torn-write");
    let (model, opt) = fresh_model_and_opt(7);
    Checkpoint::new(model.state_dict()).with_optimizer(&opt).save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    chaos::arm(FAULT_WRITE, chaos::Fault::FailWriteAfter(64));
    let err = Checkpoint::new(model.state_dict()).save(&path).unwrap_err();
    chaos::disarm(FAULT_WRITE);
    assert!(matches!(err, TorskError::Io { op: "write checkpoint", .. }), "{err}");

    assert_eq!(std::fs::read(&path).unwrap(), good, "previous checkpoint must survive");
    let stem = path.file_name().unwrap().to_string_lossy().into_owned();
    let leftovers: Vec<String> = std::fs::read_dir(path.parent().unwrap())
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with(&stem) && n.contains(".tmp."))
        .collect();
    assert!(leftovers.is_empty(), "no partial files may remain: {leftovers:?}");
    Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
}

/// A corrupted checkpoint (bit rot, torn copy) must fail with a typed
/// `Corrupt` error naming the failure — never load a wrong state dict.
#[test]
fn corrupted_checkpoint_is_a_typed_error() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let path = scratch("bitrot");
    let (model, _) = fresh_model_and_opt(7);
    Checkpoint::new(model.state_dict()).save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = Checkpoint::load(&path).unwrap_err();
    assert!(
        matches!(err, TorskError::Corrupt { ref what, .. } if what == "checksum mismatch"),
        "{err}"
    );
    std::fs::remove_file(&path).unwrap();
}

/// The resumed batch stream itself (no training in the loop) is bitwise
/// identical to the tail of an uninterrupted epoch, at any worker count.
#[test]
fn resumed_batch_stream_is_bitwise_identical_to_the_tail() {
    let fingerprint = |dl: &DataLoader| -> Vec<(Vec<u32>, Vec<u32>)> {
        dl.iter()
            .map(|(x, y)| {
                (
                    x.to_vec::<f32>().into_iter().map(f32::to_bits).collect(),
                    y.to_vec::<f32>().into_iter().map(f32::to_bits).collect(),
                )
            })
            .collect()
    };
    let full = {
        let dl = loader(0);
        dl.set_epoch(5);
        fingerprint(&dl)
    };
    for workers in [0, 4] {
        let dl = loader(workers);
        dl.resume(5, 3);
        assert_eq!(
            fingerprint(&dl),
            full[3..],
            "resumed tail at workers={workers} must match the uninterrupted epoch"
        );
    }
}

/// A hogwild worker killed mid-run (SIGKILL — the shape of an OOM kill or
/// an operator `kill -9`) must surface as a typed per-rank diagnostic from
/// `fork_workers`: the parent reaps every rank (no hang, no zombie), the
/// error names the dead rank, its pid, and `killed by signal 9`, and the
/// surviving ranks' shared-memory updates are intact. The victim's loop is
/// deadline-bounded and exits 0 if never killed, so a failed kill shows up
/// as a loud "expected Err, got Ok" — never a silent success.
#[test]
fn killed_hogwild_worker_is_reported_per_rank() {
    use std::time::{Duration, Instant};

    use torsk::multiproc::{fork_workers, RankExit, SharedTensor};
    use torsk::tensor::DType;

    let shm = PathBuf::from("/dev/shm");
    let shm_dir = if shm.exists() { shm } else { std::env::temp_dir() };
    let tag = std::process::id();
    let params_path = shm_dir.join(format!("torsk_chaos_hogwild_{tag}"));
    let pid_path = std::env::temp_dir().join(format!("torsk_chaos_victim_pid_{tag}"));
    let _ = std::fs::remove_file(&pid_path);

    // One parameter slot per rank: survivors' totals stay deterministic
    // even though every write is lock-free.
    let params = SharedTensor::create(&params_path, &[3], DType::F32).unwrap();

    // Killer thread: poll for the victim's pid file (written atomically by
    // rank 1 via rename), then SIGKILL it. Polling is the only option — the
    // victim is another process, so no condvar can cross; the loop is
    // bounded by the same deadline as the victim itself.
    let pid_path_killer = pid_path.clone();
    let killer = std::thread::spawn(move || -> Option<i32> {
        let deadline = Instant::now() + Duration::from_secs(30);
        while Instant::now() < deadline {
            if let Ok(s) = std::fs::read_to_string(&pid_path_killer) {
                if let Ok(pid) = s.trim().parse::<i32>() {
                    // SAFETY: plain kill(2) on the pid the victim just
                    // published; worst case the pid is already reaped and
                    // kill returns ESRCH, which we ignore.
                    unsafe { libc::kill(pid, libc::SIGKILL) };
                    return Some(pid);
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        None
    });

    let p = params_path.clone();
    let pid_pub = pid_path.clone();
    let result = fork_workers(3, move |rank| {
        let st = SharedTensor::open(&p).unwrap();
        let slot = st.tensor().narrow(0, rank, 1);
        let delta = Tensor::full(&[1], 1.0);
        if rank == 1 {
            // Victim: publish our pid (write + atomic rename so the killer
            // never reads a torn file), then keep updating until killed —
            // or until the deadline, in which case exit 0 and let the
            // parent's `unwrap_err` below fail the test loudly.
            let tmp = pid_pub.with_extension("tmp");
            std::fs::write(&tmp, format!("{}", std::process::id())).unwrap();
            std::fs::rename(&tmp, &pid_pub).unwrap();
            let deadline = Instant::now() + Duration::from_secs(30);
            while Instant::now() < deadline {
                slot.add_(&delta);
            }
        } else {
            // Survivors: a short burst of updates, then a clean exit.
            for _ in 0..100 {
                slot.add_(&delta);
            }
        }
    });

    let killed_pid = killer.join().unwrap().expect("killer never saw the victim's pid file");
    let err = result.unwrap_err();
    match &err {
        TorskError::Workers { total, failed } => {
            assert_eq!(*total, 3);
            assert_eq!(failed.len(), 1, "only rank 1 was killed: {failed:?}");
            assert_eq!(failed[0].rank, 1);
            assert_eq!(failed[0].pid, killed_pid);
            assert_eq!(failed[0].exit, RankExit::Signaled(libc::SIGKILL));
        }
        other => panic!("expected TorskError::Workers, got: {other}"),
    }
    let s = err.to_string();
    assert!(s.contains("1 of 3 worker(s) failed"), "{s}");
    assert!(s.contains(&format!("rank 1 (pid {killed_pid}): killed by signal 9")), "{s}");

    // The survivors' slots are exactly 100.0 — rank 1's death neither tore
    // nor clobbered the shared state the other ranks produced.
    let final_params = params.tensor().to_vec::<f32>();
    assert_eq!(final_params[0], 100.0, "{final_params:?}");
    assert_eq!(final_params[2], 100.0, "{final_params:?}");

    params.unlink();
    let _ = std::fs::remove_file(&pid_path);
}
