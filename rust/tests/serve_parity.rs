//! Serving parity: dynamic batching must be **invisible** in the served
//! bits. N concurrent requests coalesced into dynamic batches produce
//! outputs bitwise identical to serial one-request-at-a-time inference,
//! across `PALLAS_NUM_THREADS` 1/2/8 × SIMD on/off × batch budgets
//! {1, 4, max} — the same matrix the GEMM/fused/capture parity suites
//! pin, because serving parity *rests on* those invariants (row
//! blocking never changes a row's bits).
//!
//! Also pinned here:
//! - the checkpoint is the source of truth: every worker replica is
//!   differently (randomly) initialized and then overwritten by
//!   `Server::from_checkpoint`, so matching bits prove the *file*
//!   defined the weights;
//! - bucket padding makes the capture guard cache converge: across any
//!   batch split the worker sees at most `log2(max_batch)+1` shapes, so
//!   guard misses (and captured graphs) are bounded by the bucket count
//!   while every later batch is a **hit** — no recapture under steady
//!   traffic, regardless of how timing split the batches;
//! - coalescing provably happens (mean batch size > 1) without timing
//!   sleeps, by wedging the single worker on a chaos [`Gate`] while
//!   requests pile into one batch;
//! - profiler spans recorded on serve worker threads appear in the
//!   merged cross-thread report (`serve:batch` + per-op spans).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use torsk::data::stack_into_batch;
use torsk::kernels::set_num_threads;
use torsk::kernels::simd::set_force_scalar;
use torsk::nn::{Linear, Module, ReLU, Sequential};
use torsk::rng::Rng;
use torsk::serialize::Checkpoint;
use torsk::serve::{ServeConfig, Server};
use torsk::tensor::Tensor;
use torsk::testing::chaos::{Gate, RequestFaults};

/// Serializes tests that touch process-global knobs (seed epoch, kernel
/// thread count, forced-scalar mode, the profiler).
static SERIAL: Mutex<()> = Mutex::new(());

static NEXT_FILE: AtomicUsize = AtomicUsize::new(0);

fn scratch(tag: &str) -> std::path::PathBuf {
    let n = NEXT_FILE.fetch_add(1, Ordering::SeqCst);
    std::env::temp_dir().join(format!("torsk-serve-{}-{n}-{tag}.ckpt", std::process::id()))
}

const IN: usize = 8;
const HID: usize = 16;
const OUT: usize = 4;
/// 3 client threads × 8 requests.
const N_REQ: usize = 24;

fn build_arch() -> Box<dyn Module> {
    Box::new(Sequential::new().add(Linear::new(IN, HID)).add(ReLU).add(Linear::new(HID, OUT)))
}

/// Request input for logical request `i`, deterministic per index so
/// every matrix cell serves the identical workload.
fn req_input(i: usize) -> Tensor {
    let mut r = Rng::for_index(0x5E57E, i as u64);
    let x: Vec<f32> = (0..IN).map(|_| r.normal()).collect();
    Tensor::from_vec(x, &[IN])
}

fn bits(v: Vec<f32>) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Serial reference: one request per forward, batch dimension of 1 —
/// exactly what a `max_batch = 1` server computes per request.
fn forward_one(model: &dyn Module, x: &Tensor) -> Vec<u32> {
    torsk::autograd::no_grad(|| {
        let b = stack_into_batch(&[x]);
        bits(model.forward(&b).select(0, 0).contiguous().to_vec::<f32>())
    })
}

#[test]
fn batched_equals_serial_bitwise_across_matrix() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let path = scratch("parity");
    torsk::rng::manual_seed(0x5E12_7E57);
    let reference = build_arch();
    Checkpoint::new(reference.state_dict()).save(&path).expect("save serve checkpoint");

    let inputs: Vec<Tensor> = (0..N_REQ).map(req_input).collect();
    let expect: Vec<Vec<u32>> =
        inputs.iter().map(|x| forward_one(reference.as_ref(), x)).collect();

    for &threads in &[1usize, 2, 8] {
        for &scalar in &[false, true] {
            for &budget in &[1usize, 4, 8] {
                set_num_threads(threads);
                set_force_scalar(scalar);
                let cfg = ServeConfig::new(&[IN])
                    .with_max_batch(budget)
                    .with_max_delay(Duration::from_millis(20))
                    .with_workers(2);
                let server = Server::from_checkpoint(&path, build_arch, cfg)
                    .expect("serve from checkpoint");
                let handle = server.handle();
                let got: Vec<(usize, Vec<u32>)> = std::thread::scope(|s| {
                    let join: Vec<_> = (0..3)
                        .map(|c| {
                            let handle = handle.clone();
                            let inputs = &inputs;
                            s.spawn(move || {
                                // Submit the whole burst before waiting so
                                // the batcher has something to coalesce.
                                let pend: Vec<_> = (0..8)
                                    .map(|k| {
                                        let i = c * 8 + k;
                                        (i, handle.submit(inputs[i].clone()).unwrap())
                                    })
                                    .collect();
                                pend.into_iter()
                                    .map(|(i, p)| {
                                        (i, bits(p.wait().expect("served").to_vec::<f32>()))
                                    })
                                    .collect::<Vec<_>>()
                            })
                        })
                        .collect();
                    join.into_iter().flat_map(|j| j.join().unwrap()).collect()
                });
                assert_eq!(got.len(), N_REQ);
                for (i, out) in got {
                    assert_eq!(
                        out, expect[i],
                        "request {i} diverged from serial inference at \
                         threads={threads} scalar={scalar} budget={budget}"
                    );
                }
                let stats = server.stats();
                assert_eq!(stats.completed, N_REQ as u64);
                assert_eq!(stats.failed, 0);
                if budget == 1 {
                    // A budget of 1 *is* serial inference: one request
                    // per batch, by construction.
                    assert_eq!(stats.batches, N_REQ as u64);
                }
                let report = server.shutdown();
                assert!(!report.timed_out, "{report}");
            }
        }
    }
    set_force_scalar(false);
    set_num_threads(0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn bucketed_batches_replay_without_recapture() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    if std::env::var("PALLAS_CAPTURE").map(|v| v == "0").unwrap_or(false) {
        return; // kill switch on: there is no guard cache to assert over
    }
    // One worker = one capture session, so the counters below are exact.
    let cfg = ServeConfig::new(&[IN])
        .with_max_batch(8)
        .with_max_delay(Duration::from_millis(20))
        .with_workers(1);
    let server = Server::new(build_arch, cfg);
    let handle = server.handle();
    // Three rounds of bursts in assorted sizes. However timing splits
    // these into batches, every batch's row count pads to a bucket in
    // {1, 2, 4, 8} — so misses are bounded by the bucket count and
    // repeats MUST be guard hits.
    let mut sent = 0u64;
    for _round in 0..3 {
        for &k in &[1usize, 2, 3, 4, 5, 8] {
            let pend: Vec<_> = (0..k)
                .map(|_| handle.submit(req_input(sent as usize % N_REQ)).unwrap())
                .collect();
            sent += k as u64;
            for p in pend {
                p.wait().expect("served");
            }
        }
    }
    let stats = server.stats();
    assert_eq!(stats.completed, sent);
    assert!(stats.batches >= 18, "one batch per burst at minimum");
    // The no-recapture pin: at most one trace per bucket shape, every
    // other batch replays. Holds for ANY batch split timing produced.
    assert!(
        stats.guard_misses <= 4,
        "more guard misses than bucket shapes: {stats:?}"
    );
    assert!(stats.graphs_captured <= 4 && stats.graphs_captured >= 1, "{stats:?}");
    assert_eq!(
        stats.guard_hits,
        stats.batches - stats.guard_misses,
        "every repeated bucket shape must replay, not recapture: {stats:?}"
    );
    let report = server.shutdown();
    assert!(!report.timed_out, "{report}");
}

#[test]
fn coalescing_happens_and_pads_to_buckets() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // Wedge the only worker on request 0 (chaos gate, no sleeps): the
    // next three requests must coalesce into ONE batch of 3, padded to
    // the 4-bucket — mean batch size 2.0 and padded_rows 1, exactly.
    let faults = RequestFaults::new();
    let release = Gate::new();
    faults.stall_on(0, release.clone());
    let cfg = ServeConfig::new(&[IN])
        .with_max_batch(8)
        .with_max_delay(Duration::from_millis(100))
        .with_workers(1)
        .with_chaos(faults.clone());
    let server = Server::new(build_arch, cfg);
    let handle = server.handle();

    let p0 = handle.submit(req_input(0)).unwrap();
    assert_eq!(p0.seq(), 0);
    faults.stalled().wait(); // worker is provably wedged on request 0
    let pend: Vec<_> = (1..=3).map(|i| handle.submit(req_input(i)).unwrap()).collect();
    release.open();
    assert_eq!(p0.wait().expect("stalled request still served").shape(), &[OUT]);
    for p in pend {
        assert_eq!(p.wait().expect("served").shape(), &[OUT]);
    }

    let stats = server.stats();
    assert_eq!(stats.batches, 2, "{stats:?}"); // {0} and {1,2,3}
    assert_eq!(stats.batched_requests, 4);
    assert!((stats.mean_batch_size() - 2.0).abs() < 1e-12);
    assert_eq!(stats.padded_rows, 1, "batch of 3 pads to the 4-bucket");
    assert_eq!(stats.completed, 4);
    assert!(stats.queue.count >= 4 && stats.total.count >= 4 && stats.compute.count >= 2);
    assert!(stats.total.p99_ns >= stats.total.p50_ns);
    let report = server.shutdown();
    assert!(!report.timed_out, "{report}");
}

#[test]
fn worker_thread_spans_reach_the_merged_profile() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = ServeConfig::new(&[IN])
        .with_max_batch(4)
        .with_max_delay(Duration::from_millis(10))
        .with_workers(2);
    let server = Server::new(build_arch, cfg);
    let handle = server.handle();
    torsk::profiler::start();
    let pend: Vec<_> = (0..8).map(|i| handle.submit(req_input(i)).unwrap()).collect();
    for p in pend {
        p.wait().expect("served");
    }
    // The live aggregation the serve metrics expose: per-op totals over
    // the merged snapshot, while the profiler is still recording.
    let totals = torsk::serve::ServeStats::op_totals();
    let _ = torsk::profiler::stop();
    let batch_spans = totals.get("serve:batch").copied().unwrap_or_default();
    assert!(
        batch_spans.count >= 1,
        "serve worker spans must survive into the merged report: {totals:?}"
    );
    assert!(batch_spans.total_ns > 0);
    let report = server.shutdown();
    assert!(!report.timed_out, "{report}");
}
