//! §5.5 microbenchmark: reference counting vs deferred (GC-style)
//! reclamation.
//!
//! The paper: "by deferring the deallocation, [GC] causes the program to
//! use more memory overall … given the scarcity of GPU memory, these
//! overheads are unacceptable." We run a tensor-churn workload (allocate
//! activations, drop them — a training loop's memory rhythm) against
//! (a) torsk's immediate refcount reclamation and (b) the GcAllocator
//! with several collection thresholds, and report peak memory.

use std::sync::Arc;

use torsk::alloc::driver::HostMem;
use torsk::alloc::gc::GcAllocator;
use torsk::alloc::naive::NaiveAllocator;
use torsk::alloc::{Allocator, StreamId};

const TENSOR_BYTES: usize = 1 << 20; // 1 MiB activations
const LIVE_SET: usize = 8; // params/activations alive at once
const CHURN: usize = 256; // temporaries allocated over the run

/// Simulate a training loop's allocation pattern; return peak bytes.
fn churn(alloc: &dyn Allocator) -> u64 {
    alloc.reset_stats();
    // Long-lived "parameters".
    let params: Vec<_> =
        (0..LIVE_SET).map(|_| alloc.allocate(TENSOR_BYTES, StreamId::DEFAULT)).collect();
    // Churning "activations": allocate, use, drop immediately.
    for _ in 0..CHURN {
        let a = alloc.allocate(TENSOR_BYTES, StreamId::DEFAULT);
        let b = alloc.allocate(TENSOR_BYTES / 2, StreamId::DEFAULT);
        alloc.deallocate(b);
        alloc.deallocate(a);
    }
    let peak = alloc.stats().peak_in_use_bytes;
    for p in params {
        alloc.deallocate(p);
    }
    peak
}

fn main() {
    println!("== §5.5: peak memory, refcount vs deferred reclamation ==");
    println!(
        "workload: {LIVE_SET} live MiB-tensors + {CHURN} churned temporaries of 1.5 MiB\n"
    );

    let refcount = NaiveAllocator::new(Arc::new(HostMem::default()));
    let peak_rc = churn(&refcount);
    let ideal = (LIVE_SET * TENSOR_BYTES + TENSOR_BYTES * 3 / 2) as u64;
    println!(
        "{:<34} peak {:>8.1} MiB  (ideal {:.1} MiB)",
        "refcount (free at last use)",
        peak_rc as f64 / 1048576.0,
        ideal as f64 / 1048576.0
    );

    for threshold_mb in [4u64, 16, 64, u64::MAX / 1048576] {
        let inner = Arc::new(NaiveAllocator::new(Arc::new(HostMem::default())));
        let gc = GcAllocator::new(inner.clone(), threshold_mb.saturating_mul(1048576));
        // Peak from the inner allocator's view = live + graveyard.
        let params: Vec<_> =
            (0..LIVE_SET).map(|_| gc.allocate(TENSOR_BYTES, StreamId::DEFAULT)).collect();
        let mut peak = 0u64;
        for _ in 0..CHURN {
            let a = gc.allocate(TENSOR_BYTES, StreamId::DEFAULT);
            let b = gc.allocate(TENSOR_BYTES / 2, StreamId::DEFAULT);
            gc.deallocate(b);
            gc.deallocate(a);
            let s = inner.stats();
            peak = peak.max(s.in_use_bytes);
        }
        let label = if threshold_mb > 1_000_000 {
            "gc (never collect)".to_string()
        } else {
            format!("gc (collect at {threshold_mb} MiB dead)")
        };
        println!(
            "{label:<34} peak {:>8.1} MiB  ({:.2}x refcount), {} collections",
            peak as f64 / 1048576.0,
            peak as f64 / peak_rc as f64,
            gc.collections()
        );
        for p in params {
            gc.deallocate(p);
        }
    }

    // The explicit-trigger antipattern: users sprinkling collect() calls.
    let inner = Arc::new(NaiveAllocator::new(Arc::new(HostMem::default())));
    let gc = GcAllocator::new(inner.clone(), u64::MAX);
    let params: Vec<_> =
        (0..LIVE_SET).map(|_| gc.allocate(TENSOR_BYTES, StreamId::DEFAULT)).collect();
    let mut peak = 0u64;
    for i in 0..CHURN {
        let a = gc.allocate(TENSOR_BYTES, StreamId::DEFAULT);
        let b = gc.allocate(TENSOR_BYTES / 2, StreamId::DEFAULT);
        gc.deallocate(b);
        gc.deallocate(a);
        if i % 8 == 0 {
            gc.collect(); // the Torch7-era "hope the memory errors go away"
        }
        peak = peak.max(inner.stats().in_use_bytes);
    }
    for p in params {
        gc.deallocate(p);
    }
    println!(
        "{:<34} peak {:>8.1} MiB  ({:.2}x refcount)",
        "gc + manual collect() every 8 ops",
        peak as f64 / 1048576.0,
        peak as f64 / peak_rc as f64
    );

    println!(
        "\nshape check (paper §5.5): refcounting tracks the live set exactly; deferred\n\
         reclamation multiplies peak memory by the churn between collections."
    );
}
