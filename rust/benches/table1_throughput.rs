//! Table 1: training throughput for the six benchmark models across
//! execution modes.
//!
//! Paper columns (frameworks) map to torsk execution modes (DESIGN.md §2):
//!   NaiveEager   — Chainer stand-in: synchronous dispatch, no caching
//!                  allocator, define-by-run.
//!   Eager        — torsk/PyTorch: async stream dispatch + caching
//!                  allocator + multithreaded backward.
//!   StaticGraph  — TensorFlow/CNTK/MXNet stand-in: whole-train-step AOT
//!                  XLA graph (needs `make artifacts`).
//!
//! The reproduced claim: Eager is within ~17% of the fastest mode (the
//! paper's headline), and clearly faster than the naive define-by-run
//! baseline. Units: img/s for CNNs, tok/s for GNMT, samples/s for NCF.
//!
//! Env: TORSK_BENCH_STEPS (default 6), TORSK_BENCH_MODELS (csv).

use std::time::Instant;

use torsk::device::{self, Device};
use torsk::graph::GraphTrainer;
use torsk::models::{self, Batch, BenchModel};
use torsk::optim::{Optimizer, Sgd};
use torsk::runtime::Runtime;
use torsk::Tensor;

fn steps() -> usize {
    std::env::var("TORSK_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(6)
}

/// Eager-mode throughput (units/s).
fn eager_throughput(name: &str, naive: bool) -> f64 {
    if naive {
        device::set_async_enabled(false);
        torsk::ctx::use_naive_sim_allocator();
    } else {
        device::set_async_enabled(true);
        torsk::ctx::use_caching_sim_allocator();
    }
    torsk::rng::manual_seed(0);
    let model = models::by_name_on(name, Device::Sim).expect("model");
    let mut opt = Sgd::new(model.parameters(), 0.01);
    // Warmup.
    let b = model.make_batch(0).to_device(Device::Sim);
    model.loss(&b).backward();
    opt.zero_grad();
    device::synchronize();

    let n = steps();
    let t0 = Instant::now();
    let mut units = 0usize;
    for s in 0..n {
        opt.zero_grad();
        let batch = model.make_batch(s as u64).to_device(Device::Sim);
        let loss = model.loss(&batch);
        loss.backward();
        opt.step();
        units += batch.units();
    }
    device::synchronize();
    let thpt = units as f64 / t0.elapsed().as_secs_f64();
    // Restore defaults.
    device::set_async_enabled(true);
    torsk::ctx::use_caching_sim_allocator();
    thpt
}

/// Static-graph throughput via the AOT artifact, if present.
fn graph_throughput(name: &str) -> Option<f64> {
    let artifact = format!("{name}_step");
    let g = Runtime::global().load(&artifact).ok()?;
    torsk::rng::manual_seed(0);
    let n_batch = match name {
        "ncf" => 3,
        _ => 2,
    };
    let init: Vec<Tensor> = g.meta.inputs[n_batch..]
        .iter()
        .map(|s| Tensor::randn(&s.shape).mul_scalar(0.1))
        .collect();
    let mut trainer = GraphTrainer::new(&artifact, n_batch, &init).ok()?;
    let model = models::by_name(name).expect("model for batches");

    let make_inputs = |seed: u64| -> (Vec<Tensor>, usize) {
        match model.make_batch(seed) {
            Batch::Images(x, y) => {
                let u = x.size(0);
                (vec![x, y], u)
            }
            Batch::Seq2Seq(src, tgt) => {
                let u = tgt.numel();
                (vec![src, tgt], u)
            }
            Batch::Interactions(pairs, labels) => {
                let u = pairs.size(0);
                let users = pairs.select(1, 0).contiguous();
                let items = pairs.select(1, 1).contiguous();
                (vec![users, items, labels.reshape(&[labels.size(0)])], u)
            }
        }
    };

    // Warmup (includes XLA compile).
    let (b0, _) = make_inputs(0);
    trainer.step(&b0).ok()?;

    let n = steps();
    let t0 = Instant::now();
    let mut units = 0usize;
    for s in 0..n {
        let (batch, u) = make_inputs(s as u64);
        trainer.step(&batch).ok()?;
        units += u;
    }
    Some(units as f64 / t0.elapsed().as_secs_f64())
}

fn main() {
    let only: Vec<String> = std::env::var("TORSK_BENCH_MODELS")
        .map(|v| v.split(',').map(str::to_string).collect())
        .unwrap_or_default();

    println!("== Table 1: training throughput (units/s; higher is better) ==");
    println!("   paper claim: eager within ~17% of the fastest framework\n");
    println!(
        "{:<12} {:>12} {:>12} {:>12}   {:>14} {:>13}",
        "model", "NaiveEager", "Eager", "StaticGraph", "eager/fastest", "eager/naive"
    );

    let mut worst_ratio: f64 = f64::INFINITY;
    for name in models::TABLE1_MODELS {
        if !only.is_empty() && !only.iter().any(|m| m == name) {
            continue;
        }
        let naive = eager_throughput(name, true);
        let eager = eager_throughput(name, false);
        let graph = graph_throughput(name);
        let fastest = graph.unwrap_or(eager).max(eager).max(naive);
        let ratio = eager / fastest;
        worst_ratio = worst_ratio.min(ratio);
        println!(
            "{:<12} {:>12.1} {:>12.1} {:>12}   {:>13.1}% {:>12.2}x",
            name,
            naive,
            eager,
            graph.map(|g| format!("{g:.1}")).unwrap_or_else(|| "n/a".into()),
            100.0 * ratio,
            eager / naive,
        );
    }
    println!(
        "\nshape check: eager is within {:.0}% of the fastest mode on its worst model \
         (paper: 17%).",
        100.0 * (1.0 - worst_ratio)
    );
}
