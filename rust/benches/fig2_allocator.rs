//! Figure 2: memory-management traces.
//!
//! Trains ResNet-50 for several iterations and reports, per iteration,
//! the number of device-driver allocations, driver stall time, and wall
//! time — once with the caching allocator (the paper's annotated trace:
//! iteration 1 dominated by cudaMalloc/cudaFree, later iterations reuse
//! the cache) and once with the naive pass-through allocator (every
//! iteration looks like iteration 1).

use std::time::Instant;

use torsk::alloc::Allocator;
use torsk::device::Device;
use torsk::models::{BenchModel, ResNet50};
use torsk::optim::{Optimizer, Sgd};

struct IterRow {
    driver_allocs: u64,
    driver_frees: u64,
    stall_us: f64,
    cache_hits: u64,
    wall_ms: f64,
    loss: f32,
}

fn run(iters: usize, caching: bool) -> Vec<IterRow> {
    torsk::rng::manual_seed(0);
    let alloc: std::sync::Arc<dyn Allocator> = if caching {
        torsk::ctx::use_caching_sim_allocator()
    } else {
        torsk::ctx::use_naive_sim_allocator()
    };
    let driver = torsk::ctx::sim_driver();
    let model = torsk::device::with_default_device(Device::Sim, || ResNet50::new(3, 32, 10, 8));
    let mut opt = Sgd::new(BenchModel::parameters(&model), 0.01);

    let mut rows = vec![];
    for i in 0..iters {
        let before = alloc.stats();
        let stall0 = driver.stall_ns.load(std::sync::atomic::Ordering::Relaxed);
        let t0 = Instant::now();
        opt.zero_grad();
        let batch = model.make_batch(i as u64).to_device(Device::Sim);
        let loss = model.loss(&batch);
        loss.backward();
        opt.step();
        torsk::device::synchronize();
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let d = alloc.stats().delta(&before);
        let stall1 = driver.stall_ns.load(std::sync::atomic::Ordering::Relaxed);
        rows.push(IterRow {
            driver_allocs: d.driver_allocs,
            driver_frees: d.driver_frees,
            stall_us: (stall1 - stall0) as f64 / 1e3,
            cache_hits: d.cache_hits,
            wall_ms,
            loss: loss.item(),
        });
    }
    rows
}

fn print_rows(title: &str, rows: &[IterRow]) {
    println!("\n-- {title} --");
    println!(
        "{:<5} {:>13} {:>12} {:>12} {:>11} {:>9} {:>8}",
        "iter", "driver-allocs", "driver-frees", "stall(µs)", "cache-hits", "wall(ms)", "loss"
    );
    for (i, r) in rows.iter().enumerate() {
        println!(
            "{:<5} {:>13} {:>12} {:>12.0} {:>11} {:>9.0} {:>8.3}",
            i, r.driver_allocs, r.driver_frees, r.stall_us, r.cache_hits, r.wall_ms, r.loss
        );
    }
}

fn main() {
    let iters = std::env::var("TORSK_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    println!("== Figure 2: allocator behaviour across ResNet-50 training iterations ==");

    let caching = run(iters, true);
    print_rows("caching allocator (torsk/PyTorch §5.3)", &caching);
    let naive = run(iters, false);
    print_rows("naive allocator (every op hits cudaMalloc/cudaFree)", &naive);

    let first = &caching[0];
    let steady: f64 =
        caching[1..].iter().map(|r| r.driver_allocs as f64).sum::<f64>() / (iters - 1) as f64;
    let naive_avg: f64 = naive.iter().map(|r| r.driver_allocs as f64).sum::<f64>() / iters as f64;
    let caching_wall: f64 = caching[1..].iter().map(|r| r.wall_ms).sum::<f64>() / (iters - 1) as f64;
    let naive_wall: f64 = naive[1..].iter().map(|r| r.wall_ms).sum::<f64>() / (iters - 1) as f64;

    println!("\n== shape check (paper Figure 2) ==");
    println!(
        "caching: iteration 0 made {} driver allocations; steady state averages {:.1}",
        first.driver_allocs, steady
    );
    println!("naive  : every iteration averages {naive_avg:.0} driver allocations");
    println!(
        "steady-state iteration time: caching {caching_wall:.0} ms vs naive {naive_wall:.0} ms \
         ({:.2}x speedup from the caching allocator)",
        naive_wall / caching_wall
    );
    assert!(steady < first.driver_allocs as f64 * 0.1, "cache must eliminate driver calls");
}
