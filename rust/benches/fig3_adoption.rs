//! Figure 3: PyTorch share of framework mentions per month.
//!
//! Runs the paper's counting methodology (case-insensitive, one mention
//! per paper) over the synthetic arXiv corpus (DESIGN.md §2 substitution)
//! and checks the measured series recovers the generator's ground-truth
//! adoption curve — i.e. the *pipeline* is faithful; the corpus supplies
//! the trend the paper observed empirically.

use torsk::adoption::{
    ascii_chart, count_mentions, pytorch_share_series, AdoptionModel, FRAMEWORKS,
};

fn main() {
    let model = AdoptionModel::default();
    println!(
        "== Figure 3: framework-mention share (synthetic corpus: {} months x {} papers) ==\n",
        model.months, model.papers_per_month
    );
    let papers = model.generate(7);
    let counts = count_mentions(&papers, model.months);
    let series = pytorch_share_series(&counts);

    println!("{}", ascii_chart(&series, 14));

    println!("month  measured%  ground-truth%   papers");
    for m in (0..model.months).step_by(3) {
        println!(
            "{:>5}  {:>8.1}  {:>13.1}   {:>6}",
            m,
            series[m],
            100.0 * model.pytorch_prob(m),
            counts[m].papers_mentioning_any
        );
    }

    // Final-month share per framework (the right edge of the figure).
    println!("\nfinal-month share by framework:");
    let last = &counts[model.months - 1];
    let mut rows: Vec<(&str, f64)> = FRAMEWORKS.iter().map(|&f| (f, last.percent(f))).collect();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (f, pct) in rows {
        println!("  {f:<11} {pct:>5.1}%");
    }

    // Shape checks.
    let start = series[0];
    let end = series[model.months - 1];
    assert!(start < 10.0 && end > 40.0, "adoption curve must rise: {start} -> {end}");
    let max_err = (0..model.months)
        .map(|m| (series[m] / 100.0 - model.pytorch_prob(m)).abs())
        .fold(0.0f64, f64::max);
    println!(
        "\nshape check: rises {start:.1}% -> {end:.1}%; max |measured - truth| = {:.1} pp",
        100.0 * max_err
    );
    assert!(max_err < 0.10, "counting pipeline must track ground truth");
}
