//! End-to-end serving benchmark: the request's-eye view that
//! `BENCH_train.json`'s training-loop rows cannot see. Loads a
//! checkpointed MLP with `serve::Server::from_checkpoint`, drives it
//! with concurrent client threads, and emits `BENCH_serve.json`
//! (override with `BENCH_OUT`; schema `torsk.bench_serve.v1`) with one
//! record per (max_batch × clients) grid cell:
//!
//! ```json
//! {"max_batch": 8, "clients": 4, "requests": 256, "batches": 41,
//!  "mean_batch_size": 6.24, "padded_rows": 31, "wall_ns": 12345678,
//!  "throughput_rps": 20737.1, "p50_total_ns": 131072,
//!  "p99_total_ns": 1048576, "p50_queue_ns": 65536, "p99_queue_ns": 524288}
//! ```
//!
//! Latency quantiles come straight from the server's lock-free log2
//! histograms (`ServeStats`), so a quantile is the upper edge of its
//! bucket — at most 2x the true value, monotone across rows.
//!
//! Before any timing, two pins (each exits nonzero on failure):
//! - **serving parity**: a burst served through dynamic batches must be
//!   bitwise identical to serial one-at-a-time inference on the same
//!   checkpoint — batching must be invisible in the served bits;
//! - **coalescing**: the pinned concurrent run must show mean batch
//!   size > 1 — the batcher demonstrably batches under load (the
//!   acceptance headline), not just forwards singletons.
//!
//! `BENCH_SMOKE=1` runs a tiny config and validates the schema (wired
//! into CI via `make bench-smoke`).

use std::time::{Duration, Instant};

use torsk::data::stack_into_batch;
use torsk::nn::{self, Module};
use torsk::rng::Rng;
use torsk::serialize::Checkpoint;
use torsk::serve::{ServeConfig, Server};
use torsk::Tensor;

struct Config {
    din: usize,
    hidden: usize,
    classes: usize,
    /// Requests per client per grid cell (split into bursts).
    reqs_per_client: usize,
    /// Requests a client submits before waiting on any of them — the
    /// concurrency each client keeps in flight.
    burst: usize,
}

#[derive(Clone, Debug)]
struct Record {
    max_batch: usize,
    clients: usize,
    requests: u64,
    batches: u64,
    mean_batch_size: f64,
    padded_rows: u64,
    wall_ns: u64,
    throughput_rps: f64,
    p50_total_ns: u64,
    p99_total_ns: u64,
    p50_queue_ns: u64,
    p99_queue_ns: u64,
}

impl Record {
    fn to_json(&self) -> String {
        format!(
            "{{\"max_batch\": {}, \"clients\": {}, \"requests\": {}, \"batches\": {}, \
             \"mean_batch_size\": {:.2}, \"padded_rows\": {}, \"wall_ns\": {}, \
             \"throughput_rps\": {:.1}, \"p50_total_ns\": {}, \"p99_total_ns\": {}, \
             \"p50_queue_ns\": {}, \"p99_queue_ns\": {}}}",
            self.max_batch,
            self.clients,
            self.requests,
            self.batches,
            self.mean_batch_size,
            self.padded_rows,
            self.wall_ns,
            self.throughput_rps,
            self.p50_total_ns,
            self.p99_total_ns,
            self.p50_queue_ns,
            self.p99_queue_ns,
        )
    }
}

fn build_arch_for(cfg: &'static Config) -> Box<dyn Module> {
    Box::new(
        nn::Sequential::new()
            .add(nn::Linear::new(cfg.din, cfg.hidden))
            .add(nn::ReLU)
            .add(nn::Linear::new(cfg.hidden, cfg.classes)),
    )
}

/// Deterministic request input for logical index `i` — the same stream
/// every run and every grid cell, independent of the global seed state.
fn req_input(cfg: &Config, i: u64) -> Tensor {
    let mut r = Rng::for_index(0xBE_5E57E, i);
    let x: Vec<f32> = (0..cfg.din).map(|_| r.normal()).collect();
    Tensor::from_vec(x, &[cfg.din])
}

fn bits(v: Vec<f32>) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// One grid cell: serve `clients x reqs_per_client` requests from
/// `clients` threads (bursts of `cfg.burst`), return the measured row.
fn run_cell(
    cfg: &'static Config,
    ckpt: &std::path::Path,
    max_batch: usize,
    clients: usize,
) -> Record {
    let scfg = ServeConfig::new(&[cfg.din])
        .with_max_batch(max_batch)
        .with_max_delay(Duration::from_millis(2))
        .with_workers(2)
        .with_queue_depth(256);
    let server =
        Server::from_checkpoint(ckpt, move || build_arch_for(cfg), scfg).expect("serve checkpoint");
    let handle = server.handle();

    // Warm-up burst: trace the capture buckets and fill the allocator
    // cache so the measured window replays steady state.
    let warm: Vec<_> = (0..max_batch as u64)
        .map(|i| handle.submit(req_input(cfg, i)).unwrap())
        .collect();
    for p in warm {
        p.wait().expect("warm-up served");
    }
    let warm_stats = server.stats();

    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let handle = handle.clone();
            s.spawn(move || {
                let base = (c * cfg.reqs_per_client) as u64;
                let mut done = 0;
                while done < cfg.reqs_per_client {
                    let take = cfg.burst.min(cfg.reqs_per_client - done);
                    let pend: Vec<_> = (0..take)
                        .map(|k| handle.submit(req_input(cfg, base + (done + k) as u64)).unwrap())
                        .collect();
                    done += take;
                    for p in pend {
                        p.wait().expect("served");
                    }
                }
            });
        }
    });
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let d = server.stats().delta(&warm_stats);
    let report = server.shutdown();
    if report.timed_out {
        eprintln!("serve_loop: shutdown timed out at max_batch={max_batch} clients={clients}");
        std::process::exit(1);
    }
    let requests = (clients * cfg.reqs_per_client) as u64;
    assert_eq!(d.completed, requests, "every request must be served: {d:?}");
    Record {
        max_batch,
        clients,
        requests,
        batches: d.batches,
        mean_batch_size: d.mean_batch_size(),
        padded_rows: d.padded_rows,
        wall_ns,
        throughput_rps: requests as f64 / (wall_ns as f64 / 1e9),
        p50_total_ns: d.total.p50_ns,
        p99_total_ns: d.total.p99_ns,
        p50_queue_ns: d.queue.p50_ns,
        p99_queue_ns: d.queue.p99_ns,
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    // 'static so worker-thread model factories can borrow it freely.
    let cfg: &'static Config = if smoke {
        &Config { din: 8, hidden: 16, classes: 4, reqs_per_client: 32, burst: 4 }
    } else {
        &Config { din: 64, hidden: 128, classes: 10, reqs_per_client: 256, burst: 8 }
    };
    let batch_grid: &[usize] = if smoke { &[1, 4] } else { &[1, 4, 8, 16] };
    let client_grid: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 8] };

    // The checkpoint is the model: save once, every server (and the
    // serial reference) loads identical weights from the file.
    torsk::rng::manual_seed(0xBE7C_5E12);
    let reference = build_arch_for(cfg);
    let ckpt = std::env::temp_dir()
        .join(format!("torsk-bench-serve-{}.ckpt", std::process::id()));
    Checkpoint::new(reference.state_dict()).save(&ckpt).expect("save bench checkpoint");

    // ---- pin 1: serving parity (batched == serial, bitwise) -------------
    // ---- pin 2: coalescing (mean batch size > 1 under load) -------------
    let n_pin = 16u64;
    let expect: Vec<Vec<u32>> = (0..n_pin)
        .map(|i| {
            torsk::autograd::no_grad(|| {
                let b = stack_into_batch(&[&req_input(cfg, i)]);
                bits(reference.forward(&b).select(0, 0).contiguous().to_vec::<f32>())
            })
        })
        .collect();
    {
        let scfg = ServeConfig::new(&[cfg.din])
            .with_max_batch(8)
            .with_max_delay(Duration::from_millis(20))
            .with_workers(1);
        let server = Server::from_checkpoint(&ckpt, move || build_arch_for(cfg), scfg)
            .expect("serve checkpoint");
        let handle = server.handle();
        // Submit the whole burst before waiting so the batcher coalesces.
        let pend: Vec<_> = (0..n_pin).map(|i| handle.submit(req_input(cfg, i)).unwrap()).collect();
        for (i, p) in pend.into_iter().enumerate() {
            let got = bits(p.wait().expect("served").to_vec::<f32>());
            if got != expect[i] {
                eprintln!("serve_loop: request {i} served bits differ from serial inference");
                std::process::exit(1);
            }
        }
        let stats = server.stats();
        if stats.mean_batch_size() <= 1.0 {
            eprintln!(
                "serve_loop: no coalescing under concurrent load (mean batch size {:.2})",
                stats.mean_batch_size()
            );
            std::process::exit(1);
        }
        println!(
            "pins ok: {n_pin} batched requests bitwise == serial; mean batch size {:.2} \
             over {} batches ({} padded rows)",
            stats.mean_batch_size(),
            stats.batches,
            stats.padded_rows
        );
        let report = server.shutdown();
        assert!(!report.timed_out, "{report}");
    }

    // ---- measured grid ---------------------------------------------------
    let mut records: Vec<Record> = Vec::new();
    for &mb in batch_grid {
        for &clients in client_grid {
            let r = run_cell(cfg, &ckpt, mb, clients);
            println!(
                "max_batch={mb} clients={clients}: {:.1} req/s, mean batch {:.2}, \
                 p50 {:.3} ms, p99 {:.3} ms",
                r.throughput_rps,
                r.mean_batch_size,
                r.p50_total_ns as f64 / 1e6,
                r.p99_total_ns as f64 / 1e6
            );
            records.push(r);
        }
    }
    let _ = std::fs::remove_file(&ckpt);

    // ---- report ----------------------------------------------------------
    println!("\n== BENCH_serve ({}) ==", if smoke { "smoke" } else { "full" });
    println!(
        "{:>9} {:>8} {:>9} {:>8} {:>10} {:>12} {:>11} {:>11}",
        "max_batch", "clients", "requests", "batches", "mean_batch", "req/s", "p50(ms)", "p99(ms)"
    );
    for r in &records {
        println!(
            "{:>9} {:>8} {:>9} {:>8} {:>10.2} {:>12.1} {:>11.3} {:>11.3}",
            r.max_batch,
            r.clients,
            r.requests,
            r.batches,
            r.mean_batch_size,
            r.throughput_rps,
            r.p50_total_ns as f64 / 1e6,
            r.p99_total_ns as f64 / 1e6
        );
    }
    let global = torsk::serve::serve_stats();
    println!(
        "\nprocess totals: {} requests, {} batches, {} graphs captured, {} guard hits",
        global.requests, global.batches, global.graphs_captured, global.guard_hits
    );
    report_batching_win(&records);

    // ---- emit + validate JSON --------------------------------------------
    let mut json = String::new();
    json.push_str("{\n  \"schema\": \"torsk.bench_serve.v1\",\n");
    json.push_str(&format!(
        "  \"smoke\": {},\n  \"threads_available\": {},\n  \"model\": \"mlp\",\n  \
         \"dims\": {{\"din\": {}, \"hidden\": {}, \"classes\": {}}},\n  \
         \"workers\": 2,\n  \"records\": [\n",
        smoke,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        cfg.din,
        cfg.hidden,
        cfg.classes,
    ));
    for (i, r) in records.iter().enumerate() {
        json.push_str("    ");
        json.push_str(&r.to_json());
        json.push_str(if i + 1 == records.len() { "\n" } else { ",\n" });
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json).expect("write BENCH_serve.json");
    println!("wrote {out_path}");

    if let Err(e) = validate_schema(&json, records.len()) {
        eprintln!("BENCH_serve.json schema validation FAILED: {e}");
        std::process::exit(1);
    }
    println!("schema ok: torsk.bench_serve.v1, {} records", records.len());
}

/// The headline comparison: at max concurrency, throughput with real
/// batching headroom vs the forced-singleton (`max_batch = 1`) server.
fn report_batching_win(records: &[Record]) {
    let max_clients = records.iter().map(|r| r.clients).max().unwrap_or(1);
    let singleton = records.iter().find(|r| r.max_batch == 1 && r.clients == max_clients);
    let batched = records
        .iter()
        .filter(|r| r.clients == max_clients)
        .max_by(|a, b| a.throughput_rps.total_cmp(&b.throughput_rps));
    if let (Some(s), Some(b)) = (singleton, batched) {
        println!(
            "dynamic batching at {} clients: {:.1} req/s (max_batch={}) vs {:.1} \
             singleton ({:.2}x)",
            max_clients,
            b.throughput_rps,
            b.max_batch,
            s.throughput_rps,
            b.throughput_rps / s.throughput_rps
        );
    }
}

/// Minimal schema check (no JSON dependency), in the `BENCH_train.json`
/// style: the envelope declares the schema id and every record carries
/// all required keys, one record per grid cell.
fn validate_schema(json: &str, expected: usize) -> Result<(), String> {
    if !json.contains("\"schema\": \"torsk.bench_serve.v1\"") {
        return Err("missing schema id".into());
    }
    let recs: Vec<&str> =
        json.match_indices("{\"max_batch\": ").map(|(i, _)| &json[i..]).collect();
    if recs.len() != expected {
        return Err(format!("expected {expected} records, found {}", recs.len()));
    }
    for (i, r) in recs.iter().enumerate() {
        let end = r.find('}').ok_or_else(|| format!("record {i}: unterminated"))?;
        let body = &r[..end];
        for key in [
            "\"max_batch\"",
            "\"clients\"",
            "\"requests\"",
            "\"batches\"",
            "\"mean_batch_size\"",
            "\"padded_rows\"",
            "\"wall_ns\"",
            "\"throughput_rps\"",
            "\"p50_total_ns\"",
            "\"p99_total_ns\"",
            "\"p50_queue_ns\"",
            "\"p99_queue_ns\"",
        ] {
            if !body.contains(key) {
                return Err(format!("record {i}: missing {key}"));
            }
        }
    }
    Ok(())
}
