//! §5.4 microbenchmark: shared-memory tensor transport vs pipe
//! serialization.
//!
//! The paper: the stock multiprocessing primitives use "the same form of
//! serialization used for on-disk persistence, which is inefficient when
//! dealing with large arrays", so torch.multiprocessing moves tensor data
//! to shared memory instead. We measure both transports across sizes and
//! an all-reduce built on the shared-memory primitives.

use std::path::PathBuf;
use std::time::Instant;

use torsk::multiproc::{allreduce_mean, fork_workers, pipe_roundtrip, SharedTensor, ShmBarrier};
use torsk::{DType, Tensor};

fn shm_dir() -> PathBuf {
    let d = PathBuf::from("/dev/shm");
    if d.exists() {
        d
    } else {
        std::env::temp_dir()
    }
}

fn bench_pipe(n_elems: usize, reps: usize) -> f64 {
    let t = Tensor::rand(&[n_elems]);
    let t0 = Instant::now();
    for _ in 0..reps {
        let back = pipe_roundtrip(&t).expect("pipe");
        std::hint::black_box(back);
    }
    let secs = t0.elapsed().as_secs_f64();
    (n_elems * 4 * reps) as f64 / secs / 1e6 // MB/s
}

fn bench_shm(n_elems: usize, reps: usize) -> f64 {
    let path = shm_dir().join(format!("torsk_bench_shm_{}_{n_elems}", std::process::id()));
    let st = SharedTensor::create(&path, &[n_elems], DType::F32).unwrap();
    let t = Tensor::rand(&[n_elems]);
    let t0 = Instant::now();
    for _ in 0..reps {
        // "Send": producer writes into shared memory once...
        st.copy_from(&t);
        // ..."receive": consumer maps and reads (zero-copy view + one copy
        // out to make the comparison fair with the pipe's full roundtrip).
        let back = st.tensor().to_vec::<f32>();
        std::hint::black_box(back);
    }
    let secs = t0.elapsed().as_secs_f64();
    st.unlink();
    (n_elems * 4 * reps) as f64 / secs / 1e6
}

fn bench_shm_zero_copy(n_elems: usize, reps: usize) -> f64 {
    // The §4.2 claim: handing over a shared tensor is O(1) — "extremely
    // cheap, constant time no matter how large the converted arrays are".
    let path = shm_dir().join(format!("torsk_bench_shm0_{}_{n_elems}", std::process::id()));
    let st = SharedTensor::create(&path, &[n_elems], DType::F32).unwrap();
    let t0 = Instant::now();
    for _ in 0..reps {
        let view = st.tensor(); // map, no data movement
        std::hint::black_box(view.shape());
    }
    let secs = t0.elapsed().as_secs_f64();
    st.unlink();
    secs / reps as f64 * 1e9 // ns per handover
}

fn main() {
    println!("== §5.4: tensor transport between processes ==\n");
    println!(
        "{:>10} {:>14} {:>14} {:>10}   {:>17}",
        "size", "pipe MB/s", "shm MB/s", "speedup", "zero-copy ns/send"
    );
    for &kb in &[4usize, 64, 1024, 16 * 1024, 65 * 1024] {
        let n = kb * 1024 / 4;
        let reps = (64 * 1024 / kb).clamp(2, 64);
        let pipe = bench_pipe(n, reps);
        let shm = bench_shm(n, reps);
        let zc = bench_shm_zero_copy(n, 1000);
        println!(
            "{:>8}KB {:>14.0} {:>14.0} {:>9.1}x   {:>17.0}",
            kb,
            pipe,
            shm,
            shm / pipe,
            zc
        );
    }

    // All-reduce latency across 4 worker processes.
    println!("\nall-reduce (mean) across 4 forked workers:");
    for &len in &[1024usize, 262_144] {
        let scratch_path = shm_dir().join(format!("torsk_bench_ar_{}_{len}", std::process::id()));
        let timing_path = shm_dir().join(format!("torsk_bench_art_{}_{len}", std::process::id()));
        let scratch = SharedTensor::create(&scratch_path, &[len], DType::F32).unwrap();
        let timings = SharedTensor::create(&timing_path, &[4], DType::F32).unwrap();
        let (p1, p2) = (scratch_path.clone(), timing_path.clone());
        fork_workers(4, move |rank| {
            let scratch = SharedTensor::open(&p1).unwrap();
            let timings = SharedTensor::open(&p2).unwrap();
            let barrier = ShmBarrier::on(&scratch, 4);
            let local = Tensor::full(&[len], rank as f32);
            let t0 = Instant::now();
            let reps = 5;
            for _ in 0..reps {
                // Zero the accumulator between rounds (rank 0).
                if rank == 0 {
                    scratch.tensor().zero_();
                }
                barrier.wait();
                let out = allreduce_mean(&local, &scratch, &barrier, 4);
                std::hint::black_box(out);
                barrier.wait();
            }
            let us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;
            let slot = timings.tensor().narrow(0, rank, 1);
            torsk::ops::copy_into_view_public(&slot, &Tensor::from_slice(&[us as f32]));
        })
        .expect("allreduce workers");
        let per_rank = timings.tensor().to_vec::<f32>();
        println!(
            "  {len:>7} elems: {:>8.0} µs/op (max over ranks {:?})",
            per_rank.iter().cloned().fold(0.0f32, f32::max),
            per_rank.iter().map(|v| *v as i64).collect::<Vec<_>>()
        );
        scratch.unlink();
        timings.unlink();
    }
    println!("\nshape check (paper §5.4): shared memory beats serialization by a widening\n\
              margin as tensors grow; handing over a mapped tensor is O(1).");
}
