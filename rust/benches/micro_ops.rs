//! The standing op-level benchmark harness (the repo's TorchBench):
//! elementwise chains, broadcasts, reductions, softmax, matmul shapes and
//! MLP / conv-block fwd+bwd, swept across sizes × thread counts, plus a
//! 100-iteration training loop that exercises the caching allocator and
//! the dispatcher's output-stealing.
//!
//! Every run emits `BENCH_ops.json` (override the path with `BENCH_OUT`)
//! with one record per (op, size, threads):
//!
//! ```json
//! {"op": "elementwise_chain", "size": 1048576, "threads": 4,
//!  "ns_per_iter": 1234.5, "bytes_allocated": 4194304,
//!  "cache_hit_rate": 0.98, "reused_outputs": 3}
//! ```
//!
//! `bytes_allocated` and `reused_outputs` are per-iteration; the hit rate
//! covers the measured window of the host caching allocator. The
//! `gemm:packed:*` / `gemm:unpacked-ref:*` pairs additionally carry a
//! `gflops` key (2·m·n·k / ns) at threads 1/2/8, and the packed results
//! are bit-compared across those thread counts before timing. The
//! `gemm:packed:*` and `fused:*` rows come in **simd pairs** — one record
//! with the runtime-detected vector path active (`"simd": true`) and one
//! forced scalar (`"simd": false`, the `PALLAS_SIMD=0` path) — and the
//! packed GEMM results are bit-compared between the two modes before
//! timing. The `eager:mlp_block` / `captured:mlp_block` pair times the
//! same op chain plain vs replayed through a `GraphCapture` session
//! (schema torsk.bench_ops.v2), with the two modes bit-compared before
//! timing. Future PRs append their numbers next to these — this file is
//! the trajectory to beat. `BENCH_SMOKE=1` runs one tiny iteration of
//! everything and validates the JSON schema (wired into CI as
//! `make bench-smoke`).

use std::time::Instant;

use torsk::alloc::Allocator;
use torsk::dispatch;
use torsk::kernels::simd::set_force_scalar;
use torsk::nn::{self, Module};
use torsk::ops;
use torsk::optim::{Optimizer, Sgd};
use torsk::Tensor;

#[derive(Clone, Debug)]
struct Record {
    op: String,
    size: usize,
    threads: usize,
    ns_per_iter: f64,
    bytes_allocated: u64,
    cache_hit_rate: f64,
    reused_outputs: u64,
    /// GFLOP/s — set on the `gemm:*` records (2*m*n*k / ns), absent
    /// elsewhere. An optional extra key since schema torsk.bench_ops.v1.
    gflops: Option<f64>,
    /// Whether the runtime-detected vector path was allowed for this
    /// record (`false` = forced scalar, the `PALLAS_SIMD=0` path). Set on
    /// the paired `gemm:packed:*` / `fused:*` rows, absent elsewhere. An
    /// optional extra key since schema torsk.bench_ops.v1.
    simd: Option<bool>,
}

impl Record {
    fn to_json(&self) -> String {
        let gflops = match self.gflops {
            Some(g) => format!(", \"gflops\": {g:.2}"),
            None => String::new(),
        };
        let simd = match self.simd {
            Some(s) => format!(", \"simd\": {s}"),
            None => String::new(),
        };
        format!(
            "{{\"op\": \"{}\", \"size\": {}, \"threads\": {}, \"ns_per_iter\": {:.1}, \
             \"bytes_allocated\": {}, \"cache_hit_rate\": {:.4}, \"reused_outputs\": {}{}{}}}",
            self.op,
            self.size,
            self.threads,
            self.ns_per_iter,
            self.bytes_allocated,
            self.cache_hit_rate,
            self.reused_outputs,
            gflops,
            simd
        )
    }
}

/// Time `f` for `reps` iterations at `threads` effective kernel threads,
/// reading allocator + output-reuse deltas over the measured window.
fn measure(op: &str, size: usize, threads: usize, reps: usize, mut f: impl FnMut()) -> Record {
    torsk::kernels::set_num_threads(threads);
    for _ in 0..2usize.min(reps) {
        f(); // warm the allocator cache and the pool
    }
    let alloc = torsk::ctx::host_allocator();
    let s0 = alloc.stats();
    let (_, h0) = dispatch::output_reuse_stats();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    let d = alloc.stats().delta(&s0);
    let (_, h1) = dispatch::output_reuse_stats();
    torsk::kernels::set_num_threads(0);
    Record {
        op: op.to_string(),
        size,
        threads,
        ns_per_iter: ns,
        bytes_allocated: d.allocated_bytes_total / reps as u64,
        cache_hit_rate: d.cache_hit_rate(),
        reused_outputs: (h1 - h0) / reps as u64,
        gflops: None,
        simd: None,
    }
}

/// Measure `f` twice — vector path active, then forced scalar (what
/// `PALLAS_SIMD=0` gives every call) — producing the paired records the
/// SIMD work is graded on. The two records share op/size/threads and
/// differ only in the `simd` key. On hosts where detection lands on
/// scalar anyway, the pair still exists and the rows simply coincide.
fn measure_simd_pair(
    op: &str,
    size: usize,
    threads: usize,
    reps: usize,
    mut f: impl FnMut(),
) -> [Record; 2] {
    set_force_scalar(false);
    let mut on = measure(op, size, threads, reps, &mut f);
    on.simd = Some(true);
    set_force_scalar(true);
    let mut off = measure(op, size, threads, reps, &mut f);
    off.simd = Some(false);
    set_force_scalar(false);
    [on, off]
}

fn thread_sweep() -> Vec<usize> {
    let max = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut ts: Vec<usize> = [1usize, 2, 4, 8].iter().copied().filter(|&t| t <= max).collect();
    if !ts.contains(&max) && max > 1 {
        ts.push(max);
    }
    if ts.is_empty() {
        ts.push(1);
    }
    ts
}

fn reps_for(size: usize, smoke: bool) -> usize {
    if smoke {
        1
    } else if size <= 1 << 16 {
        200
    } else if size <= 1 << 20 {
        40
    } else {
        12
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_ops.json".to_string());
    let threads = thread_sweep();
    let mut records: Vec<Record> = Vec::new();
    torsk::rng::manual_seed(0);

    // ---- elementwise chain: relu(sigmoid(a*b) + a), owned hot path ----
    let chain_sizes: &[usize] =
        if smoke { &[1 << 12] } else { &[1 << 16, 1 << 20, 1 << 22] };
    for &n in chain_sizes {
        let a = Tensor::rand(&[n]);
        let b = Tensor::rand(&[n]);
        for &t in &threads {
            records.push(measure("elementwise_chain", n, t, reps_for(n, smoke), || {
                let tmp = &a * &b;
                let tmp = dispatch::call_owned("sigmoid", vec![tmp], &[]);
                let tmp = tmp + &a;
                let y = dispatch::call_owned("relu", vec![tmp], &[]);
                std::hint::black_box(&y);
            }));
        }
    }

    // ---- fused tape kernels vs their unfused compositions ----
    // The acceptance shape: at 1e6 elements the fused single-pass kernel
    // must allocate at most one output buffer and beat the composed chain
    // on ns_per_iter. `fused:*` vs `unfused:*` rows pair up directly.
    {
        let fused_sizes: &[usize] = if smoke { &[1 << 12] } else { &[1 << 20] };
        for &n in fused_sizes {
            let x = Tensor::randn(&[n]);
            let t = Tensor::rand(&[n]);
            let p = ops::sigmoid(&x);
            let unfused_bce = |p: &Tensor, t: &Tensor| {
                let eps = 1e-7f32;
                let pc = ops::clamp(p, eps, 1.0 - eps);
                let log_p = ops::log(&pc);
                let log_1p = ops::log(&ops::add_scalar(&ops::neg(&pc), 1.0));
                let omt = ops::add_scalar(&ops::neg(t), 1.0);
                let total = ops::add(&ops::mul(t, &log_p), &ops::mul(&omt, &log_1p));
                ops::neg(&ops::mean(&total))
            };
            for &th in &threads {
                let reps = reps_for(n, smoke);
                records.extend(measure_simd_pair("fused:sigmoid_bce", n, th, reps, || {
                    std::hint::black_box(ops::bce_with_logits(&x, &t));
                }));
                records.push(measure("unfused:sigmoid_bce", n, th, reps, || {
                    std::hint::black_box(unfused_bce(&ops::sigmoid(&x), &t));
                }));
                records.extend(measure_simd_pair("fused:mse", n, th, reps, || {
                    std::hint::black_box(ops::mse_loss(&x, &t));
                }));
                records.push(measure("unfused:mse", n, th, reps, || {
                    let d = ops::sub(&x, &t);
                    std::hint::black_box(ops::mean(&ops::mul(&d, &d)));
                }));
                records.extend(measure_simd_pair("fused:bce", n, th, reps, || {
                    std::hint::black_box(ops::bce_loss(&p, &t));
                }));
                records.push(measure("unfused:bce", n, th, reps, || {
                    std::hint::black_box(unfused_bce(&p, &t));
                }));
                records.extend(measure_simd_pair("fused:gelu", n, th, reps, || {
                    std::hint::black_box(ops::gelu(&x));
                }));
                records.push(measure("unfused:gelu", n, th, reps, || {
                    let a = 0.044_715f32;
                    let c = 0.797_884_56f32;
                    let x3 = ops::mul(&ops::mul(&x, &x), &x);
                    let inner = ops::add(&ops::mul_scalar(&x3, a), &x);
                    let tt = ops::tanh(&ops::mul_scalar(&inner, c));
                    std::hint::black_box(ops::mul(
                        &ops::add_scalar(&tt, 1.0),
                        &ops::mul_scalar(&x, 0.5),
                    ));
                }));
            }
        }
        // Layer-norm tail: [R, D] with per-row stats and [D] affine.
        let (r, d) = if smoke { (16, 64) } else { (1024, 1024) };
        let c = Tensor::randn(&[r, d]);
        let is = ops::add_scalar(&Tensor::rand(&[r, 1]), 0.5);
        let gamma = Tensor::randn(&[d]);
        let beta = Tensor::randn(&[d]);
        for &th in &threads {
            let reps = reps_for(r * d, smoke);
            records.extend(measure_simd_pair("fused:ln_tail", r * d, th, reps, || {
                let args: [&Tensor; 4] = [&c, &is, &gamma, &beta];
                std::hint::black_box(dispatch::call("fused:ln_tail", &args, &[]));
            }));
            records.push(measure("unfused:ln_tail", r * d, th, reps, || {
                std::hint::black_box(ops::add(&ops::mul(&ops::mul(&c, &is), &gamma), &beta));
            }));
        }
        // Fused optimizer step vs the composed update (one param tensor).
        let n = if smoke { 1 << 12 } else { 1 << 20 };
        let (lr, b1, b2, eps2, wd) = (1e-3f32, 0.9f32, 0.999f32, 1e-8f32, 0.0f32);
        let (bc1, bc2) = (1.0 - b1, 1.0 - b2);
        let p1 = Tensor::randn(&[n]);
        let g1 = Tensor::randn(&[n]);
        let m1 = Tensor::zeros(&[n]);
        let v1 = Tensor::zeros(&[n]);
        let adam_params = [
            dispatch::Param::F32(lr),
            dispatch::Param::F32(b1),
            dispatch::Param::F32(b2),
            dispatch::Param::F32(eps2),
            dispatch::Param::F32(wd),
            dispatch::Param::F32(bc1),
            dispatch::Param::F32(bc2),
        ];
        for &th in &threads {
            let reps = reps_for(n, smoke);
            records.extend(measure_simd_pair("fused:adam_step", n, th, reps, || {
                dispatch::call("fused:adam_step", &[&p1, &g1, &m1, &v1], &adam_params);
            }));
            records.push(measure("unfused:adam_step", n, th, reps, || {
                m1.mul_scalar_(b1);
                m1.axpy_(1.0 - b1, &g1);
                let g2 = ops::mul(&g1, &g1);
                v1.mul_scalar_(b2);
                v1.axpy_(1.0 - b2, &g2);
                let mhat = ops::mul_scalar(&m1, 1.0 / bc1);
                let vhat = ops::mul_scalar(&v1, 1.0 / bc2);
                let denom = ops::add_scalar(&ops::sqrt(&vhat), eps2);
                let update = ops::div(&mhat, &denom);
                p1.axpy_(-lr, &update);
            }));
        }
    }

    // ---- broadcast add: [R, C] + [C] (Suffix plan) ----
    {
        let (r, c) = if smoke { (64, 64) } else { (1024, 1024) };
        let m = Tensor::rand(&[r, c]);
        let v = Tensor::rand(&[c]);
        for &t in &threads {
            records.push(measure("broadcast_add", r * c, t, reps_for(r * c, smoke), || {
                std::hint::black_box(ops::add(&m, &v));
            }));
        }
    }

    // ---- reductions ----
    let sum_sizes: &[usize] = if smoke { &[1 << 12] } else { &[1 << 20, 1 << 22] };
    for &n in sum_sizes {
        let a = Tensor::rand(&[n]);
        for &t in &threads {
            records.push(measure("sum", n, t, reps_for(n, smoke), || {
                std::hint::black_box(ops::sum(&a));
            }));
        }
    }
    {
        let (r, c) = if smoke { (64, 64) } else { (1024, 1024) };
        let a = Tensor::rand(&[r, c]);
        for &t in &threads {
            records.push(measure("sum_dims_rows", r * c, t, reps_for(r * c, smoke), || {
                std::hint::black_box(ops::sum_dims(&a, &[1], false));
            }));
            records.push(measure("sum_dims_cols", r * c, t, reps_for(r * c, smoke), || {
                std::hint::black_box(ops::sum_dims(&a, &[0], false));
            }));
        }
    }

    // ---- softmax over rows (>=1M elements in the full run) ----
    {
        let (r, c) = if smoke { (32, 64) } else { (1024, 1024) };
        let x = Tensor::rand(&[r, c]);
        for &t in &threads {
            records.push(measure("softmax", r * c, t, reps_for(r * c, smoke), || {
                std::hint::black_box(ops::softmax_last(&x));
            }));
        }
    }

    // ---- packed vs unpacked GEMM: GFLOP/s at threads 1/2/8 ----
    // Paired `gemm:packed:*` / `gemm:unpacked-ref:*` rows at the four
    // acceptance shapes (square, tall-skinny, linear-layer, conv-im2col).
    // The packed results are also bit-compared across thread counts here,
    // so even the smoke run exercises the determinism contract.
    {
        use torsk::kernels::matmul::{sgemm, sgemm_unpacked, Trans};
        let shapes: &[(&str, usize, usize, usize)] = if smoke {
            &[
                ("square", 32, 32, 32),
                ("tall_skinny", 4, 64, 48),
                ("linear_layer", 16, 24, 40),
                ("conv_im2col", 8, 49, 36),
            ]
        } else {
            &[
                ("square", 256, 256, 256),
                ("tall_skinny", 8, 1024, 1024),
                ("linear_layer", 128, 256, 784),
                ("conv_im2col", 64, 3136, 576),
            ]
        };
        for &(name, m, n, k) in shapes {
            let a = Tensor::randn(&[m, k]).to_vec::<f32>();
            let b = Tensor::randn(&[k, n]).to_vec::<f32>();
            let flop = (2 * m * n * k) as f64;
            let mut pinned: Option<Vec<f32>> = None;
            for &t in &[1usize, 2, 8] {
                // Determinism pin: identical bits at every thread count,
                // and identical bits between the vector path and forced
                // scalar (the PALLAS_SIMD=0 contract).
                torsk::kernels::set_num_threads(t);
                let mut c = vec![0.0f32; m * n];
                sgemm(Trans::N, Trans::N, m, n, k, 1.0, &a, &b, 0.0, &mut c);
                let mut c_scalar = vec![0.0f32; m * n];
                set_force_scalar(true);
                sgemm(Trans::N, Trans::N, m, n, k, 1.0, &a, &b, 0.0, &mut c_scalar);
                set_force_scalar(false);
                torsk::kernels::set_num_threads(0);
                if c_scalar != c {
                    eprintln!("gemm:{name}: simd and forced-scalar bits differ at {t} threads");
                    std::process::exit(1);
                }
                if let Some(p) = &pinned {
                    if p != &c {
                        eprintln!("gemm:{name}: packed result differs at {t} threads");
                        std::process::exit(1);
                    }
                } else {
                    pinned = Some(c);
                }

                let reps = if smoke { 1 } else { 20 };
                let mut c = vec![0.0f32; m * n];
                let mut pair =
                    measure_simd_pair(&format!("gemm:packed:{name}"), m * n * k, t, reps, || {
                        sgemm(Trans::N, Trans::N, m, n, k, 1.0, &a, &b, 0.0, &mut c);
                        std::hint::black_box(&c);
                    });
                for r in &mut pair {
                    r.gflops = Some(flop / r.ns_per_iter);
                }
                records.extend(pair);
                let mut r = measure(&format!("gemm:unpacked-ref:{name}"), m * n * k, t, reps, || {
                    sgemm_unpacked(m, n, k, 1.0, &a, &b, 0.0, &mut c);
                    std::hint::black_box(&c);
                });
                r.gflops = Some(flop / r.ns_per_iter);
                records.push(r);
            }
        }
    }

    // ---- matmul: square and tall-skinny (the grain-fix shape) ----
    {
        let n = if smoke { 32 } else { 256 };
        let a = Tensor::randn(&[n, n]);
        let b = Tensor::randn(&[n, n]);
        for &t in &threads {
            records.push(measure("matmul_square", n * n, t, if smoke { 1 } else { 20 }, || {
                std::hint::black_box(ops::matmul(&a, &b));
            }));
        }
        let (m, k) = if smoke { (4, 64) } else { (8, 1024) };
        let a = Tensor::randn(&[m, k]);
        let b = Tensor::randn(&[k, k]);
        for &t in &threads {
            records.push(measure("matmul_tall_skinny", m * k, t, if smoke { 1 } else { 30 }, || {
                std::hint::black_box(ops::matmul(&a, &b));
            }));
        }
    }

    // ---- MLP forward+backward ----
    {
        let (batch, din, dh, dout) = if smoke { (8, 32, 16, 4) } else { (128, 784, 256, 10) };
        let model = nn::Sequential::new()
            .add(nn::Linear::new(din, dh))
            .add(nn::ReLU)
            .add(nn::Linear::new(dh, dout));
        let x = Tensor::randn(&[batch, din]);
        let target = Tensor::randn(&[batch, dout]);
        let params = model.parameters();
        for &t in &threads {
            records.push(measure("mlp_fwd_bwd", batch * din, t, if smoke { 1 } else { 20 }, || {
                let loss = ops::mse_loss(&model.forward(&x), &target);
                loss.backward();
                for p in &params {
                    p.set_grad(None);
                }
            }));
        }
    }

    // ---- graph capture: the same MLP op chain, eager vs replayed ----
    // Paired rows: `eager:mlp_block` runs the chain through the normal
    // dispatcher; `captured:mlp_block` replays the fused/planned graph a
    // `GraphCapture` session compiled from it. The two modes are
    // bit-compared before timing — a divergence aborts the whole run.
    {
        let (batch, din, dh, dout) = if smoke { (8, 32, 16, 4) } else { (128, 784, 256, 10) };
        let w1 = Tensor::randn(&[dh, din]);
        let b1 = Tensor::randn(&[dh]);
        let w2 = Tensor::randn(&[dout, dh]);
        let b2 = Tensor::randn(&[dout]);
        let x = Tensor::randn(&[batch, din]);
        let target = Tensor::randn(&[batch, dout]);
        let block = |ins: &[&Tensor]| {
            let h = ops::relu(&ops::linear(ins[0], &w1, Some(&b1)));
            let y = ops::linear(&h, &w2, Some(&b2));
            ops::mse_loss(&y, &target)
        };
        let sess = dispatch::GraphCapture::new("bench:mlp_block");
        let eager_bits: Vec<u32> =
            block(&[&x]).to_vec::<f32>().iter().map(|v| v.to_bits()).collect();
        let _trace = sess.run(&[&x], block);
        let replay_bits: Vec<u32> =
            sess.run(&[&x], block).to_vec::<f32>().iter().map(|v| v.to_bits()).collect();
        if eager_bits != replay_bits {
            eprintln!("mlp_block: captured replay bits differ from eager");
            std::process::exit(1);
        }
        for &t in &threads {
            let reps = if smoke { 1 } else { 40 };
            records.push(measure("eager:mlp_block", batch * din, t, reps, || {
                std::hint::black_box(block(&[&x]));
            }));
            records.push(measure("captured:mlp_block", batch * din, t, reps, || {
                std::hint::black_box(sess.run(&[&x], block));
            }));
        }
    }

    // ---- conv residual block forward+backward ----
    {
        let (n, c, hw) = if smoke { (1, 4, 8) } else { (4, 16, 16) };
        let x = Tensor::randn(&[n, c, hw, hw]);
        let w = Tensor::randn(&[c, c, 3, 3]).requires_grad(true);
        for &t in &threads {
            records.push(measure(
                "resnet_block_fwd_bwd",
                n * c * hw * hw,
                t,
                if smoke { 1 } else { 10 },
                || {
                    let y = ops::conv2d(&x, &w, None, 1, 1, 1);
                    let y = ops::relu(&y);
                    let y = ops::add(&y, &x);
                    ops::sum(&y).backward();
                    w.set_grad(None);
                },
            ));
        }
    }

    // ---- 100-iteration MLP training loop: the allocator/caching story ----
    {
        let (batch, din, dh, dout) = if smoke { (8, 32, 16, 4) } else { (64, 256, 128, 10) };
        let iters = if smoke { 2 } else { 100 };
        let model = nn::Sequential::new()
            .add(nn::Linear::new(din, dh))
            .add(nn::Tanh)
            .add(nn::Linear::new(dh, dout));
        let x = Tensor::randn(&[batch, din]);
        let target = Tensor::randn(&[batch, dout]);
        let mut opt = Sgd::new(model.parameters(), 0.01);
        let step = |opt: &mut Sgd| {
            let loss = ops::mse_loss(&model.forward(&x), &target);
            opt.zero_grad();
            loss.backward();
            opt.step();
        };
        // Warm the cache with a few steps, then measure the loop.
        for _ in 0..3usize.min(iters) {
            step(&mut opt);
        }
        let alloc = torsk::ctx::host_allocator();
        let s0 = alloc.stats();
        let (_, h0) = dispatch::output_reuse_stats();
        let t0 = Instant::now();
        for _ in 0..iters {
            step(&mut opt);
        }
        let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let d = alloc.stats().delta(&s0);
        let (_, h1) = dispatch::output_reuse_stats();
        records.push(Record {
            op: "mlp_train_loop".to_string(),
            size: batch * din,
            threads: torsk::kernels::num_threads(),
            ns_per_iter: ns,
            bytes_allocated: d.allocated_bytes_total / iters as u64,
            cache_hit_rate: d.cache_hit_rate(),
            reused_outputs: (h1 - h0) / iters as u64,
            gflops: None,
            simd: None,
        });
    }

    // ---- report ----
    println!("== BENCH_ops ({} records{}) ==", records.len(), if smoke { ", smoke" } else { "" });
    println!(
        "{:<22} {:>10} {:>3} {:>14} {:>12} {:>6} {:>6}",
        "op", "size", "t", "ns/iter", "bytes/iter", "hit%", "reuse"
    );
    for r in &records {
        println!(
            "{:<22} {:>10} {:>3} {:>14.0} {:>12} {:>5.1}% {:>6}",
            r.op,
            r.size,
            r.threads,
            r.ns_per_iter,
            r.bytes_allocated,
            r.cache_hit_rate * 100.0,
            r.reused_outputs
        );
    }
    for op in ["elementwise_chain", "softmax"] {
        let big: Vec<&Record> =
            records.iter().filter(|r| r.op == op && r.size >= 1 << 20).collect();
        let t1 = big.iter().find(|r| r.threads == 1);
        // Prefer the 4-thread row (the acceptance shape); fall back to the
        // widest sweep point so <4-core hosts still report scaling.
        let tn = big
            .iter()
            .find(|r| r.threads == 4)
            .or_else(|| big.iter().filter(|r| r.threads > 1).max_by_key(|r| r.threads));
        match (t1, tn) {
            (Some(a), Some(b)) => println!(
                "speedup {op} @ {} elems: {:.2}x at {} threads vs 1",
                a.size,
                a.ns_per_iter / b.ns_per_iter,
                b.threads
            ),
            _ => println!("speedup {op}: skipped (no >=1M multi-thread records in this run)"),
        }
    }
    for shape in ["square", "tall_skinny", "linear_layer", "conv_im2col"] {
        for &t in &[1usize, 8] {
            let p = records
                .iter()
                .find(|r| r.op == format!("gemm:packed:{shape}") && r.threads == t);
            let u = records
                .iter()
                .find(|r| r.op == format!("gemm:unpacked-ref:{shape}") && r.threads == t);
            if let (Some(p), Some(u)) = (p, u) {
                println!(
                    "gemm {shape} @ {} threads: packed {:.2} GFLOP/s vs unpacked {:.2} ({:.2}x)",
                    t,
                    p.gflops.unwrap_or(0.0),
                    u.gflops.unwrap_or(0.0),
                    u.ns_per_iter / p.ns_per_iter
                );
            }
        }
    }
    for op in ["sigmoid_bce", "mse", "bce", "gelu", "ln_tail", "adam_step"] {
        let f = records.iter().find(|r| r.op == format!("fused:{op}") && r.threads == 1);
        let u = records.iter().find(|r| r.op == format!("unfused:{op}") && r.threads == 1);
        if let (Some(f), Some(u)) = (f, u) {
            println!(
                "fusion {op} @ {} elems: {:.2}x vs unfused at 1 thread ({} vs {} bytes/iter)",
                f.size,
                u.ns_per_iter / f.ns_per_iter,
                f.bytes_allocated,
                u.bytes_allocated
            );
        }
    }
    {
        let e = records.iter().find(|r| r.op == "eager:mlp_block" && r.threads == 1);
        let c = records.iter().find(|r| r.op == "captured:mlp_block" && r.threads == 1);
        if let (Some(e), Some(c)) = (e, c) {
            println!(
                "capture mlp_block @ {} elems: {:.2}x vs eager at 1 thread ({} vs {} bytes/iter)",
                e.size,
                e.ns_per_iter / c.ns_per_iter,
                c.bytes_allocated,
                e.bytes_allocated
            );
        }
    }
    for op in ["gemm:packed:square", "fused:sigmoid_bce", "fused:ln_tail"] {
        let on = records.iter().find(|r| r.op == op && r.threads == 1 && r.simd == Some(true));
        let off = records.iter().find(|r| r.op == op && r.threads == 1 && r.simd == Some(false));
        if let (Some(on), Some(off)) = (on, off) {
            println!(
                "simd {op}: {:.2}x vs forced scalar at 1 thread",
                off.ns_per_iter / on.ns_per_iter
            );
        }
    }

    // ---- emit + validate JSON ----
    let mut json = String::new();
    json.push_str("{\n  \"schema\": \"torsk.bench_ops.v2\",\n");
    json.push_str(&format!(
        "  \"threads_available\": {},\n  \"smoke\": {},\n  \"records\": [\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        smoke
    ));
    for (i, r) in records.iter().enumerate() {
        json.push_str("    ");
        json.push_str(&r.to_json());
        json.push_str(if i + 1 == records.len() { "\n" } else { ",\n" });
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json).expect("write BENCH_ops.json");
    println!("wrote {out_path}");

    if let Err(e) = validate_schema(&json, records.len()) {
        eprintln!("BENCH_ops.json schema validation FAILED: {e}");
        std::process::exit(1);
    }
    println!("schema ok: torsk.bench_ops.v2, {} records", records.len());
}

/// Minimal schema check (no JSON dependency): the envelope declares the
/// schema id, every record carries all six required keys, and the v2
/// capture rows come as a complete eager/captured pair.
fn validate_schema(json: &str, expected: usize) -> Result<(), String> {
    if !json.contains("\"schema\": \"torsk.bench_ops.v2\"") {
        return Err("missing schema id".into());
    }
    // v2: the graph-capture benchmark emits paired mode rows.
    for op in ["\"op\": \"eager:mlp_block\"", "\"op\": \"captured:mlp_block\""] {
        if !json.contains(op) {
            return Err(format!("v2 capture pair incomplete: missing {op}"));
        }
    }
    let recs: Vec<&str> = json.match_indices("{\"op\": ").map(|(i, _)| &json[i..]).collect();
    if recs.len() != expected {
        return Err(format!("expected {expected} records, found {}", recs.len()));
    }
    for (i, r) in recs.iter().enumerate() {
        let end = r.find('}').ok_or_else(|| format!("record {i}: unterminated"))?;
        let body = &r[..end];
        for key in [
            "\"op\"",
            "\"size\"",
            "\"threads\"",
            "\"ns_per_iter\"",
            "\"bytes_allocated\"",
            "\"cache_hit_rate\"",
            "\"reused_outputs\"",
        ] {
            if !body.contains(key) {
                return Err(format!("record {i}: missing {key}"));
            }
        }
        // GEMM rows additionally carry throughput.
        if body.contains("\"op\": \"gemm:") && !body.contains("\"gflops\"") {
            return Err(format!("record {i}: gemm record missing \"gflops\""));
        }
        // The vectorized rows come in simd/scalar pairs — each record
        // must say which half of the pair it is.
        if (body.contains("\"op\": \"gemm:packed:") || body.contains("\"op\": \"fused:"))
            && !body.contains("\"simd\"")
        {
            return Err(format!("record {i}: paired record missing \"simd\""));
        }
    }
    Ok(())
}
