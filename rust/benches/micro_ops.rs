//! Micro-benchmarks of the subsystems the paper optimizes (§5.1–§5.3):
//! allocator latency, dispatch overhead, kernel throughput. These are the
//! knobs the §Perf pass iterates on; numbers land in EXPERIMENTS.md.

use std::time::Instant;

use torsk::alloc::driver::HostMem;
use torsk::alloc::{caching::CachingAllocator, naive::NaiveAllocator, Allocator, StreamId};
use torsk::device::{self, Device};
use torsk::ops;
use torsk::Tensor;

fn time_ns(reps: usize, mut f: impl FnMut()) -> f64 {
    // Warmup.
    for _ in 0..3.min(reps) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_nanos() as f64 / reps as f64
}

fn main() {
    println!("== micro-benchmarks ==\n");

    // ---- allocator -----------------------------------------------------
    println!("-- allocator: alloc+free latency (1 MiB block) --");
    let caching = CachingAllocator::new(std::sync::Arc::new(HostMem::default()));
    let naive = NaiveAllocator::new(std::sync::Arc::new(HostMem::default()));
    // Prime the cache.
    let b = caching.allocate(1 << 20, StreamId::DEFAULT);
    caching.deallocate(b);
    let t_cached = time_ns(10_000, || {
        let b = caching.allocate(1 << 20, StreamId::DEFAULT);
        caching.deallocate(b);
    });
    let t_naive = time_ns(10_000, || {
        let b = naive.allocate(1 << 20, StreamId::DEFAULT);
        naive.deallocate(b);
    });
    println!("  caching (hit) : {t_cached:>9.0} ns");
    println!("  pass-through  : {t_naive:>9.0} ns   ({:.1}x)", t_naive / t_cached);
    // Against the simulated device driver the gap is the Figure 2 story;
    // here both use host malloc so the delta is pure allocator overhead.

    // ---- dispatch ------------------------------------------------------
    println!("\n-- dispatch: per-op overhead --");
    let t_queue = {
        let x = Tensor::ones(&[16]).to_sim();
        device::synchronize();
        let t = time_ns(5_000, || {
            let y = ops::add_scalar(&x, 1.0);
            std::hint::black_box(&y);
        });
        device::synchronize();
        t
    };
    let t_inline = {
        let x = Tensor::ones(&[16]);
        time_ns(5_000, || {
            let y = ops::add_scalar(&x, 1.0);
            std::hint::black_box(&y);
        })
    };
    println!("  queue on stream (async)  : {t_queue:>9.0} ns/op (host-side cost)");
    println!("  execute inline on host   : {t_inline:>9.0} ns/op");
    // Both paths above run through dispatch::call (registry lookup, schema
    // check, key resolution) — the numbers are the all-in per-op cost.
    println!("  registry: {} ops registered", torsk::dispatch::op_names().len());

    // ---- kernels ---------------------------------------------------------
    println!("\n-- matmul GFLOP/s (f32, square) --");
    for &n in &[64usize, 128, 256, 512, 1024] {
        torsk::rng::manual_seed(0);
        let a = Tensor::randn(&[n, n]);
        let b = Tensor::randn(&[n, n]);
        let reps = (1usize << 28) / (2 * n * n * n).max(1);
        let ns = time_ns(reps.clamp(2, 50), || {
            std::hint::black_box(ops::matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / ns;
        println!("  {n:>5}x{n:<5} {gflops:>7.2} GFLOP/s");
    }

    println!("\n-- conv2d (N=8, C=32->32, 16x16, k=3) --");
    {
        torsk::rng::manual_seed(0);
        let x = Tensor::randn(&[8, 32, 16, 16]);
        let w = Tensor::randn(&[32, 32, 3, 3]);
        let ns = time_ns(10, || {
            std::hint::black_box(ops::conv2d(&x, &w, None, 1, 1, 1));
        });
        let flops = 2.0 * 8.0 * 32.0 * 16.0 * 16.0 * 32.0 * 9.0;
        println!("  forward: {:.2} ms, {:.2} GFLOP/s", ns / 1e6, flops / ns);
    }

    println!("\n-- elementwise bandwidth (add, 16M elems) --");
    {
        let n = 16 * 1024 * 1024;
        let a = Tensor::ones(&[n]);
        let b = Tensor::ones(&[n]);
        let ns = time_ns(10, || {
            std::hint::black_box(ops::add(&a, &b));
        });
        // 2 reads + 1 write, 4 bytes each.
        println!("  {:.1} GB/s", 3.0 * 4.0 * n as f64 / ns);
    }

    println!("\n-- backward engine: graph overhead (chain of 100 tiny ops) --");
    {
        let x = Tensor::ones(&[4]).requires_grad(true);
        let ns = time_ns(200, || {
            let mut y = x.clone();
            for _ in 0..100 {
                y = ops::mul_scalar(&y, 1.001);
            }
            ops::sum(&y).backward();
            x.set_grad(None);
        });
        println!("  {:.1} µs per fwd+bwd of 100-op chain ({:.0} ns/op)", ns / 1e3, ns / 200.0);
    }

    // Keep the Sim device drained so the process exits cleanly.
    let _ = Device::Sim;
    device::synchronize();
}
