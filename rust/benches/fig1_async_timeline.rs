//! Figure 1: asynchronous dataflow timeline.
//!
//! Profiles the first operators of a ResNet-50 forward pass on the
//! simulated accelerator and renders the paper's two-row view: the host
//! CPU queueing work (top) racing ahead of the stream executing it
//! (bottom). Reports the paper's quantities:
//!   - time the host takes to *queue* an op vs the device to *execute* it
//!     (paper: "GPU execution takes around three times longer than CPU
//!     scheduling" on their hardware);
//!   - device utilization (paper: "almost perfect device utilization").
//!
//! Also writes a chrome://tracing JSON to target/fig1_trace.json.

use torsk::device::Device;
use torsk::models::{BenchModel, ResNet50};
use torsk::profiler::{self, Track};

fn main() {
    torsk::rng::manual_seed(0);
    torsk::ctx::use_caching_sim_allocator();
    let model = torsk::device::with_default_device(Device::Sim, || ResNet50::new(3, 32, 10, 16));
    let batch = model.make_batch(0).to_device(Device::Sim);

    // Warm the allocator cache so the timeline is steady-state (Fig 2
    // effects are measured separately).
    let _ = torsk::autograd::no_grad(|| BenchModel::loss(&model, &batch)).item();

    profiler::start();
    let loss = torsk::autograd::no_grad(|| BenchModel::loss(&model, &batch));
    let _ = loss.item(); // host blocks here; device drains
    let events = profiler::stop();

    // The Figure-1 window: launches + kernel executions only.
    let launches: Vec<_> = events
        .iter()
        .filter(|e| e.track == Track::Host && e.name.starts_with("launch "))
        .take(40)
        .cloned()
        .collect();
    let end_window = launches.last().map(|e| e.end_ns).unwrap_or(u64::MAX);
    let kernels: Vec<_> = events
        .iter()
        .filter(|e| matches!(e.track, Track::Stream(_)) && e.start_ns <= end_window * 4)
        .take(40)
        .cloned()
        .collect();

    let mut window = launches.clone();
    window.extend(kernels.iter().cloned());
    window.sort_by_key(|e| e.start_ns);
    println!("== Figure 1: first ~40 operators of ResNet-50 (steady state) ==\n");
    println!("{}", profiler::ascii_timeline(&window, 110));

    let queue_ns: u64 = launches.iter().map(|e| e.dur_ns()).sum();
    let exec_ns: u64 = kernels.iter().map(|e| e.dur_ns()).sum();
    let n = launches.len().min(kernels.len()).max(1);
    println!("host queue time  : {:>10.1} µs total, {:.2} µs/op", queue_ns as f64 / 1e3, queue_ns as f64 / 1e3 / n as f64);
    println!("device exec time : {:>10.1} µs total, {:.2} µs/op", exec_ns as f64 / 1e3, exec_ns as f64 / 1e3 / n as f64);
    println!(
        "execute/queue ratio: {:.1}x  (paper's GP100/Xeon: ~3x; higher means the host\n\
         runs even further ahead on this testbed)",
        exec_ns as f64 / queue_ns.max(1) as f64
    );

    let dev = profiler::track_stats(&events, Track::Stream(0));
    println!(
        "device utilization over the full pass: {:.1}% ({} kernels, busy {:.2} ms / extent {:.2} ms)",
        100.0 * dev.utilization(),
        dev.spans,
        dev.busy_ns as f64 / 1e6,
        dev.extent_ns() as f64 / 1e6
    );

    let json = profiler::to_chrome_trace(&events);
    std::fs::create_dir_all("target").ok();
    std::fs::write("target/fig1_trace.json", &json).ok();
    println!("\nchrome trace written to target/fig1_trace.json ({} events)", events.len());
}
