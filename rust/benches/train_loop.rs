//! End-to-end training-loop benchmark: the whole-model view that
//! `BENCH_ops.json`'s per-op records cannot see (TorchBench's argument —
//! per-op microbenchmarks miss whole-model behavior).
//!
//! Trains one fixed MLP classifier on a synthetic image dataset through
//! the `data::DataLoader` at **workers = 0, 1 and 4**, and emits
//! `BENCH_train.json` (override with `BENCH_OUT`; schema
//! `torsk.bench_train.v2`) with one record per worker count plus a
//! `"mode": "captured"` row that runs the same loop with the forward +
//! loss replayed through a `dispatch::GraphCapture` session (the eager
//! and captured per-step losses are bit-compared before timing; a
//! divergence exits nonzero):
//!
//! ```json
//! {"mode": "eager", "workers": 4, "batches": 48, "samples": 1536,
//!  "wall_ns": 123456789, "samples_per_sec": 12443.1, "stall_ns": 345678,
//!  "stall_fraction": 0.0028, "ns_per_batch": 2571974}
//! ```
//!
//! `stall_ns` is time the training thread spent blocked inside the
//! loader's `next()` — at workers = 0 that is the entire fetch+collate
//! cost; at workers = 4 it is whatever the prefetch queue failed to hide.
//! `stall_fraction` (stall / wall) is the headline: the workers=4 row
//! staying below the workers=0 row is the paper's §4.2 overlap, measured.
//!
//! Before any timing, the batch stream itself is pinned: the full first
//! epoch must be **bitwise identical** across all three worker counts
//! (ordered reassembly, seed-deterministic sampler) or the bench exits
//! nonzero. `BENCH_SMOKE=1` runs a tiny config and validates the schema
//! (wired into CI via `make bench-smoke`).

use std::sync::Arc;
use std::time::Instant;

use torsk::data::{DataLoader, SyntheticImages};
use torsk::nn::{self, Module};
use torsk::ops;
use torsk::optim::{Optimizer, Sgd};

struct Config {
    n: usize,
    channels: usize,
    hw: usize,
    classes: usize,
    batch: usize,
    hidden: usize,
    epochs: usize,
}

#[derive(Clone, Debug)]
struct Record {
    /// "eager" = normal dispatch; "captured" = forward + loss replayed
    /// through a `GraphCapture` session. New in schema v2.
    mode: &'static str,
    workers: usize,
    batches: u64,
    samples: u64,
    wall_ns: u64,
    samples_per_sec: f64,
    stall_ns: u64,
    stall_fraction: f64,
    ns_per_batch: f64,
}

impl Record {
    fn to_json(&self) -> String {
        format!(
            "{{\"mode\": \"{}\", \"workers\": {}, \"batches\": {}, \"samples\": {}, \
             \"wall_ns\": {}, \"samples_per_sec\": {:.1}, \"stall_ns\": {}, \
             \"stall_fraction\": {:.4}, \"ns_per_batch\": {:.0}}}",
            self.mode,
            self.workers,
            self.batches,
            self.samples,
            self.wall_ns,
            self.samples_per_sec,
            self.stall_ns,
            self.stall_fraction,
            self.ns_per_batch
        )
    }
}

fn build_loader(cfg: &Config, workers: usize) -> DataLoader {
    let ds = Arc::new(SyntheticImages::new(cfg.n, cfg.channels, cfg.hw, cfg.hw, cfg.classes));
    DataLoader::new(ds, cfg.batch).shuffle(true).seed(42).drop_last(true).workers(workers)
}

fn build_model(cfg: &Config) -> nn::Sequential {
    // Same weights for every worker count: seed right before construction.
    torsk::rng::manual_seed(0);
    let din = cfg.channels * cfg.hw * cfg.hw;
    nn::Sequential::new()
        .add(nn::Linear::new(din, cfg.hidden))
        .add(nn::ReLU)
        .add(nn::Linear::new(cfg.hidden, cfg.classes))
}

type Fingerprint = Vec<(Vec<f32>, Vec<i64>)>;

/// The full epoch-0 batch stream as raw bytes-equivalent vectors.
fn epoch_fingerprint(loader: &DataLoader) -> Fingerprint {
    loader.set_epoch(0);
    loader.iter().map(|(x, y)| (x.to_vec::<f32>(), y.to_vec::<i64>())).collect()
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_train.json".to_string());
    let cfg = if smoke {
        Config { n: 64, channels: 3, hw: 8, classes: 10, batch: 16, hidden: 16, epochs: 1 }
    } else {
        Config { n: 512, channels: 3, hw: 32, classes: 10, batch: 32, hidden: 128, epochs: 3 }
    };
    let worker_counts = [0usize, 1, 4];

    // ---- determinism pin: identical batch stream at every worker count --
    let reference = epoch_fingerprint(&build_loader(&cfg, 0));
    for &w in &worker_counts[1..] {
        let got = epoch_fingerprint(&build_loader(&cfg, w));
        if got != reference {
            eprintln!("train_loop: batch stream at workers={w} differs from workers=0");
            std::process::exit(1);
        }
    }
    println!(
        "batch stream pinned: {} batches bitwise-identical at workers 0/1/4",
        reference.len()
    );
    drop(reference);

    // ---- measured training runs ----------------------------------------
    let mut records: Vec<Record> = Vec::new();
    for &w in &worker_counts {
        let loader = build_loader(&cfg, w);
        let model = build_model(&cfg);
        let mut opt = Sgd::new(model.parameters(), 0.05).with_momentum(0.9);
        let din = cfg.channels * cfg.hw * cfg.hw;

        // Warm-up epoch: populate the allocator cache and the packed-
        // weight cache so the measured window is steady state.
        let mut last_loss = 0.0f32;
        for (x, y) in loader.iter() {
            let logits = model.forward(&x.reshape(&[x.size(0), din]));
            let loss = ops::cross_entropy(&logits, &y);
            opt.zero_grad();
            loss.backward();
            opt.step();
            last_loss = loss.item();
        }

        let s0 = loader.stats();
        let t0 = Instant::now();
        let mut samples = 0u64;
        for _ in 0..cfg.epochs {
            for (x, y) in loader.iter() {
                samples += x.size(0) as u64;
                let logits = model.forward(&x.reshape(&[x.size(0), din]));
                let loss = ops::cross_entropy(&logits, &y);
                opt.zero_grad();
                loss.backward();
                opt.step();
                last_loss = loss.item();
            }
        }
        let wall_ns = t0.elapsed().as_nanos() as u64;
        let d = loader.stats().delta(&s0);
        records.push(Record {
            mode: "eager",
            workers: w,
            batches: d.batches,
            samples,
            wall_ns,
            samples_per_sec: samples as f64 / (wall_ns as f64 / 1e9),
            stall_ns: d.stall_ns,
            stall_fraction: d.stall_ns as f64 / wall_ns as f64,
            ns_per_batch: wall_ns as f64 / d.batches.max(1) as f64,
        });
        println!(
            "workers={w}: {:.1} samples/s, stall {:.2}% of wall, final loss {last_loss:.4}",
            records.last().unwrap().samples_per_sec,
            records.last().unwrap().stall_fraction * 100.0
        );
    }

    // ---- captured-mode run: same loop through a GraphCapture session ----
    // The optimizer updates parameters in place, so the session's
    // captured externals (the weight handles) track every step; only the
    // batch tensors are session inputs. The first batch is run through
    // both modes with identical weights and the loss bits compared —
    // eager semantics are the contract, so any divergence is fatal.
    {
        let loader = build_loader(&cfg, 0);
        let model = build_model(&cfg);
        let mut opt = Sgd::new(model.parameters(), 0.05).with_momentum(0.9);
        let din = cfg.channels * cfg.hw * cfg.hw;
        let sess = torsk::dispatch::GraphCapture::new("bench:train_step");
        let fwd = |ins: &[&torsk::Tensor]| ops::cross_entropy(&model.forward(ins[0]), ins[1]);

        // Cross-mode bitwise pin before any timing.
        {
            let (x0, y0) = loader.iter().next().expect("empty loader");
            let x0r = x0.reshape(&[x0.size(0), din]);
            let eager_loss = ops::cross_entropy(&model.forward(&x0r), &y0);
            let _trace = sess.run(&[&x0r, &y0], fwd);
            let replayed = sess.run(&[&x0r, &y0], fwd);
            if eager_loss.to_vec::<f32>().iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
                != replayed.to_vec::<f32>().iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
            {
                eprintln!("train_loop: captured replay loss bits differ from eager");
                std::process::exit(1);
            }
        }

        let mut last_loss = 0.0f32;
        // Warm-up epoch (steady-state caches, like the eager runs).
        for (x, y) in loader.iter() {
            let xr = x.reshape(&[x.size(0), din]);
            let loss = sess.run(&[&xr, &y], fwd);
            opt.zero_grad();
            loss.backward();
            opt.step();
            last_loss = loss.item();
        }
        let s0 = loader.stats();
        let t0 = Instant::now();
        let mut samples = 0u64;
        for _ in 0..cfg.epochs {
            for (x, y) in loader.iter() {
                samples += x.size(0) as u64;
                let xr = x.reshape(&[x.size(0), din]);
                let loss = sess.run(&[&xr, &y], fwd);
                opt.zero_grad();
                loss.backward();
                opt.step();
                last_loss = loss.item();
            }
        }
        let wall_ns = t0.elapsed().as_nanos() as u64;
        let d = loader.stats().delta(&s0);
        records.push(Record {
            mode: "captured",
            workers: 0,
            batches: d.batches,
            samples,
            wall_ns,
            samples_per_sec: samples as f64 / (wall_ns as f64 / 1e9),
            stall_ns: d.stall_ns,
            stall_fraction: d.stall_ns as f64 / wall_ns as f64,
            ns_per_batch: wall_ns as f64 / d.batches.max(1) as f64,
        });
        println!(
            "captured: {:.1} samples/s, final loss {last_loss:.4}",
            records.last().unwrap().samples_per_sec
        );
    }

    // ---- report ---------------------------------------------------------
    println!("\n== BENCH_train ({}) ==", if smoke { "smoke" } else { "full" });
    println!(
        "{:>9} {:>7} {:>8} {:>8} {:>14} {:>14} {:>8}",
        "mode", "workers", "batches", "samples", "samples/s", "ns/batch", "stall%"
    );
    for r in &records {
        println!(
            "{:>9} {:>7} {:>8} {:>8} {:>14.1} {:>14.0} {:>7.2}%",
            r.mode,
            r.workers,
            r.batches,
            r.samples,
            r.samples_per_sec,
            r.ns_per_batch,
            r.stall_fraction * 100.0
        );
    }
    let w0 = records.iter().find(|r| r.mode == "eager" && r.workers == 0).unwrap();
    let w4 = records.iter().find(|r| r.mode == "eager" && r.workers == 4).unwrap();
    println!(
        "\nloader overlap: stall {:.2}% at workers=0 -> {:.2}% at workers=4 \
         ({:.2}x samples/s)",
        w0.stall_fraction * 100.0,
        w4.stall_fraction * 100.0,
        w4.samples_per_sec / w0.samples_per_sec
    );
    if !smoke && w4.stall_fraction >= w0.stall_fraction {
        println!(
            "WARNING: workers=4 stall fraction did not drop below workers=0 \
             (acceptance expects overlap on this config)"
        );
    }
    if let Some(cap) = records.iter().find(|r| r.mode == "captured") {
        println!(
            "graph capture: {:.0} ns/batch captured vs {:.0} eager at workers=0 ({:.2}x)",
            cap.ns_per_batch,
            w0.ns_per_batch,
            w0.ns_per_batch / cap.ns_per_batch
        );
    }

    // ---- emit + validate JSON ------------------------------------------
    let mut json = String::new();
    json.push_str("{\n  \"schema\": \"torsk.bench_train.v2\",\n");
    json.push_str(&format!(
        "  \"smoke\": {},\n  \"threads_available\": {},\n  \"model\": \"mlp\",\n  \
         \"dataset\": {{\"n\": {}, \"channels\": {}, \"hw\": {}, \"classes\": {}}},\n  \
         \"batch_size\": {},\n  \"epochs\": {},\n  \"records\": [\n",
        smoke,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        cfg.n,
        cfg.channels,
        cfg.hw,
        cfg.classes,
        cfg.batch,
        cfg.epochs
    ));
    for (i, r) in records.iter().enumerate() {
        json.push_str("    ");
        json.push_str(&r.to_json());
        json.push_str(if i + 1 == records.len() { "\n" } else { ",\n" });
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json).expect("write BENCH_train.json");
    println!("wrote {out_path}");

    if let Err(e) = validate_schema(&json, records.len()) {
        eprintln!("BENCH_train.json schema validation FAILED: {e}");
        std::process::exit(1);
    }
    println!("schema ok: torsk.bench_train.v2, {} records", records.len());
}

/// Minimal schema check (no JSON dependency), in the `BENCH_ops.json`
/// style: the envelope declares the schema id and every record carries all
/// required keys, one record per benchmarked worker count.
fn validate_schema(json: &str, expected: usize) -> Result<(), String> {
    if !json.contains("\"schema\": \"torsk.bench_train.v2\"") {
        return Err("missing schema id".into());
    }
    let recs: Vec<&str> = json.match_indices("{\"workers\": ").map(|(i, _)| &json[i..]).collect();
    if recs.len() != expected {
        return Err(format!("expected {expected} records, found {}", recs.len()));
    }
    for (i, r) in recs.iter().enumerate() {
        let end = r.find('}').ok_or_else(|| format!("record {i}: unterminated"))?;
        let body = &r[..end];
        for key in [
            "\"workers\"",
            "\"batches\"",
            "\"samples\"",
            "\"wall_ns\"",
            "\"samples_per_sec\"",
            "\"stall_ns\"",
            "\"stall_fraction\"",
            "\"ns_per_batch\"",
        ] {
            if !body.contains(key) {
                return Err(format!("record {i}: missing {key}"));
            }
        }
    }
    Ok(())
}
