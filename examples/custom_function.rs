//! Extensibility (§4.2): a user-defined differentiable function — the
//! `torch.autograd.Function` mechanism. Users "define a new subclass …
//! that implements forward() and backward() methods"; in torsk that is an
//! op function that computes its result and registers a backward closure.
//!
//! We implement `swish(x) = x * sigmoid(beta * x)` as a custom function
//! with a hand-written derivative and check it against autograd's own
//! composition of primitives.
//!
//! Run: `cargo run --release --example custom_function`

use torsk::autograd::{self, ClosureFunction, SavedTensor};
use torsk::prelude::*;

/// Custom differentiable op: forward + hand-written vector-Jacobian
/// product, exactly the §4.2 extension contract.
fn swish_custom(x: &Tensor, beta: f32) -> Tensor {
    // forward(): compute with grad recording off — we provide the backward.
    let out = no_grad(|| {
        let s = ops::sigmoid(&x.mul_scalar(beta));
        ops::mul(x, &s)
    });
    // backward(): d/dx [x σ(βx)] = σ(βx) + βx σ(βx)(1 − σ(βx))
    if autograd::should_record(&[x]) {
        let saved = SavedTensor::save(x);
        autograd::record(&[x], &out, || {
            ClosureFunction::new("swish", move |grad_out| {
                let x = saved.unpack();
                let g = no_grad(|| {
                    let s = ops::sigmoid(&x.mul_scalar(beta));
                    let one_minus_s = ops::add_scalar(&ops::neg(&s), 1.0);
                    let ds = ops::mul(&ops::mul(&s, &one_minus_s), &x.mul_scalar(beta));
                    ops::mul(grad_out, &ops::add(&s, &ds))
                });
                vec![Some(g)]
            })
        });
    }
    out
}

/// The same function built from autograd primitives (reference).
fn swish_composed(x: &Tensor, beta: f32) -> Tensor {
    ops::mul(x, &ops::sigmoid(&x.mul_scalar(beta)))
}

fn main() {
    torsk::rng::manual_seed(5);
    let beta = 1.5;

    // Values agree.
    let x = Tensor::randn(&[64]);
    assert_close(&swish_custom(&x, beta), &swish_composed(&x, beta), 1e-5, 1e-5);
    println!("forward values match the composed reference");

    // Gradients agree with the autograd-derived ones.
    let x1 = Tensor::randn(&[64]).requires_grad(true);
    swish_custom(&x1, beta).sum().backward();
    let g_custom = x1.grad().unwrap();

    let x2 = x1.detach().contiguous().requires_grad(true);
    swish_composed(&x2, beta).sum().backward();
    let g_auto = x2.grad().unwrap();

    assert_close(&g_custom, &g_auto, 1e-4, 1e-4);
    println!("hand-written backward matches autograd composition");

    // And the custom op trains: fit y = swish(w * x) to a target w.
    let w = Tensor::from_slice(&[0.2f32]).requires_grad(true);
    let target_w = 1.3f32;
    for _ in 0..200 {
        w.set_grad(None);
        let xs = Tensor::randn(&[128]);
        let pred = swish_custom(&ops::mul(&xs, &w.expand(&[128]).contiguous()), beta);
        let tgt = no_grad(|| swish_composed(&xs.mul_scalar(target_w), beta));
        let loss = ops::mse_loss(&pred, &tgt);
        loss.backward();
        no_grad(|| w.axpy_(-0.3, &w.grad().unwrap()));
    }
    let learned = w.item();
    println!("learned w = {learned:.3} (target {target_w})");
    assert!((learned - target_w).abs() < 0.05);

    // Versioning protects the custom function too (§4.3).
    let x3 = Tensor::randn(&[4]).requires_grad(true);
    let y3 = swish_custom(&x3, beta);
    no_grad(|| x3.fill_(0.0)); // mutate a saved tensor in place
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| y3.sum().backward()));
    assert!(r.is_err(), "backward after in-place mutation must error");
    println!("tensor versioning caught the in-place mutation");

    println!("custom_function OK");
}
