//! End-to-end driver on the simulated accelerator: train ResNet-50
//! (scaled) for a few steps with async stream dispatch, the caching
//! allocator, and the profiler — then print the Figure 1/2 evidence.
//!
//! Run: `cargo run --release --example train_resnet [steps]`

use torsk::device::Device;
use torsk::models::{BenchModel, ResNet50};
use torsk::optim::{Optimizer, Sgd};
use torsk::prelude::*;
use torsk::profiler;
use torsk::alloc::Allocator;

fn main() {
    torsk::rng::manual_seed(0);
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(6);

    let model = torsk::device::with_default_device(Device::Sim, || ResNet50::new(3, 32, 10, 8));
    let mut opt = Sgd::new(BenchModel::parameters(&model), 0.05).with_momentum(0.9);
    let alloc = torsk::ctx::use_caching_sim_allocator();

    println!("training scaled ResNet-50 on the simulated accelerator");
    println!("step  loss    driver-allocs(iter)  cache-hits(iter)  ms");
    let mut first_iter_driver = 0;
    let mut steady_driver = 0;
    for step in 0..steps {
        let before = alloc.stats();
        let t0 = std::time::Instant::now();
        opt.zero_grad();
        let batch = model.make_batch(step as u64).to_device(Device::Sim);
        let loss = model.loss(&batch);
        let loss_v = loss.item(); // syncs the stream
        loss.backward();
        opt.step();
        torsk::device::synchronize();
        let d = alloc.stats().delta(&before);
        if step == 0 {
            first_iter_driver = d.driver_allocs;
        } else {
            steady_driver = d.driver_allocs;
        }
        println!(
            "{step:>4}  {loss_v:.4}  {:>19}  {:>16}  {:.0}",
            d.driver_allocs,
            d.cache_hits,
            t0.elapsed().as_millis()
        );
    }
    println!(
        "\nFigure 2 in one line: iteration 0 made {first_iter_driver} driver allocations, \
         steady state makes {steady_driver}."
    );

    // One profiled forward pass for the Figure 1 view.
    profiler::start();
    let batch = model.make_batch(99).to_device(Device::Sim);
    let loss = no_grad(|| BenchModel::loss(&model, &batch));
    let _ = loss.item();
    let events = profiler::stop();
    let head: Vec<_> = events.into_iter().take(80).collect();
    println!("\nFigure 1 timeline (first ops; host row queues, stream row executes):");
    println!("{}", profiler::ascii_timeline(&head, 100));
    println!("train_resnet OK");
}
