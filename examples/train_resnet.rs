//! End-to-end driver on the simulated accelerator: train ResNet-50
//! (scaled) through the parallel prefetching `DataLoader` with async
//! stream dispatch, the caching allocator, and the profiler — then print
//! the Figure 1/2 evidence plus the loader-overlap numbers.
//!
//! Run: `cargo run --release --example train_resnet [steps]`

use std::sync::Arc;

use torsk::alloc::Allocator;
use torsk::data::{DataLoader, SyntheticImages};
use torsk::device::Device;
use torsk::models::{Batch, BenchModel, ResNet50};
use torsk::optim::{Optimizer, Sgd};
use torsk::prelude::*;
use torsk::profiler;

fn main() {
    torsk::rng::manual_seed(0);
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(6);

    let model = torsk::device::with_default_device(Device::Sim, || ResNet50::new(3, 32, 10, 8));
    let mut opt = Sgd::new(BenchModel::parameters(&model), 0.05).with_momentum(0.9);
    let alloc = torsk::ctx::use_caching_sim_allocator();

    // The data pipeline: deterministic synthetic ImageNet stand-in,
    // shuffled per epoch from one seed, two prefetch workers collating
    // [8,3,32,32] batches in the background while the stream computes.
    let dataset = Arc::new(SyntheticImages::new(64, 3, 32, 32, 10));
    let loader = DataLoader::new(dataset, 8).shuffle(true).seed(0).drop_last(true).workers(2);

    println!("training scaled ResNet-50 on the simulated accelerator");
    println!("step  loss    driver-allocs(iter)  cache-hits(iter)  ms");
    let mut first_iter_driver = 0;
    let mut steady_driver = 0;
    let mut step = 0;
    'train: loop {
        for (x, y) in loader.iter() {
            if step >= steps {
                break 'train;
            }
            let before = alloc.stats();
            let t0 = std::time::Instant::now();
            opt.zero_grad();
            let batch = Batch::Images(x, y).to_device(Device::Sim);
            let loss = model.loss(&batch);
            let loss_v = loss.item(); // syncs the stream
            loss.backward();
            opt.step();
            torsk::device::synchronize();
            let d = alloc.stats().delta(&before);
            if step == 0 {
                first_iter_driver = d.driver_allocs;
            } else {
                steady_driver = d.driver_allocs;
            }
            println!(
                "{step:>4}  {loss_v:.4}  {:>19}  {:>16}  {:.0}",
                d.driver_allocs,
                d.cache_hits,
                t0.elapsed().as_millis()
            );
            step += 1;
        }
    }
    println!(
        "\nFigure 2 in one line: iteration 0 made {first_iter_driver} driver allocations, \
         steady state makes {steady_driver}."
    );

    // Loader overlap + batch-buffer reuse: after the warm-up above, one
    // epoch of pure loading must be served from the host allocator cache
    // (the paper's pinned-buffer reuse) — and the stall counter shows how
    // much data time the two workers hid from the training thread.
    let host = torsk::ctx::host_allocator();
    let (h0, l0) = (host.stats(), loader.stats());
    for (x, _) in loader.iter() {
        std::hint::black_box(&x);
    }
    let hd = host.stats().delta(&h0);
    let ld = loader.stats().delta(&l0);
    let rate = hd.cache_hit_rate();
    println!(
        "\nloader: {} batches, stall {:.2} ms, steady-state batch buffers {:.0}% from cache",
        ld.batches,
        ld.stall_ns as f64 / 1e6,
        rate * 100.0
    );
    assert!(
        rate > 0.5,
        "steady-state batches should hit the buffer cache (rate {rate:.3}, hits {}, \
         driver allocs {})",
        hd.cache_hits,
        hd.driver_allocs
    );

    // One profiled forward pass for the Figure 1 view (the `data:collate`
    // spans from the loader land on the host track next to the op spans).
    profiler::start();
    let (x, y) = loader.iter().next().expect("one profiled batch");
    let batch = Batch::Images(x, y).to_device(Device::Sim);
    let loss = no_grad(|| BenchModel::loss(&model, &batch));
    let _ = loss.item();
    let events = profiler::stop();
    let head: Vec<_> = events.into_iter().take(80).collect();
    println!("\nFigure 1 timeline (first ops; host row queues, stream row executes):");
    println!("{}", profiler::ascii_timeline(&head, 100));
    println!("train_resnet OK");
}
