//! Hogwild! training (§5.4): multiple worker processes updating *shared*
//! model parameters lock-free through `torsk::multiproc` shared-memory
//! tensors — "transparently handles sharing … making it easy to implement
//! techniques like Hogwild".
//!
//! Task: logistic regression on a planted linearly-separable problem.
//! The data side is the real pipeline: a deterministic `Dataset` of
//! planted examples, and inside each of the 4 forked workers a
//! `DataLoader` (one prefetch thread, rank-seeded shuffle) that feeds the
//! lock-free SGD updates into the shared parameter tensors.
//!
//! Run: `cargo run --release --example hogwild`

use std::path::PathBuf;
use std::sync::Arc;

use torsk::data::{DataLoader, Dataset};
use torsk::multiproc::{fork_workers, SharedTensor};
use torsk::prelude::*;
use torsk::rng::Rng;

const DIM: usize = 16;
const WORKERS: usize = 4;
const STEPS_PER_WORKER: usize = 300;
const BATCH: usize = 16;

/// Ground-truth weights used to plant the labels.
fn truth() -> Vec<f32> {
    (0..DIM).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect()
}

/// Linearly separable examples, deterministic per index: x ~ N(0,1)^DIM,
/// y = [w*·x > 0].
struct Planted {
    n: usize,
    seed: u64,
    w: Vec<f32>,
}

impl Planted {
    fn new(n: usize, seed: u64) -> Planted {
        Planted { n, seed, w: truth() }
    }
}

impl Dataset for Planted {
    fn len(&self) -> usize {
        self.n
    }

    fn get(&self, index: usize) -> (Tensor, Tensor) {
        let mut r = Rng::for_index(self.seed, index as u64);
        let x: Vec<f32> = (0..DIM).map(|_| r.normal()).collect();
        let dot: f32 = x.iter().zip(&self.w).map(|(a, b)| a * b).sum();
        let y = if dot > 0.0 { 1.0f32 } else { 0.0 };
        (Tensor::from_vec(x, &[DIM]), Tensor::from_vec(vec![y], &[1]))
    }
}

fn accuracy(w: &Tensor, b: &Tensor, n_batches: usize, seed: u64) -> f32 {
    let eval = DataLoader::new(Arc::new(Planted::new(n_batches * BATCH, seed)), BATCH);
    let mut correct = 0;
    no_grad(|| {
        for (x, y) in eval.iter() {
            let p = ops::sigmoid(&ops::add(&ops::matmul(&x, &w.reshape(&[DIM, 1])), b));
            let pv = p.to_vec::<f32>();
            let yv = y.to_vec::<f32>();
            correct += pv.iter().zip(&yv).filter(|(p, y)| (**p > 0.5) == (**y > 0.5)).count();
        }
    });
    correct as f32 / (n_batches * BATCH) as f32
}

fn shm_dir() -> PathBuf {
    let d = PathBuf::from("/dev/shm");
    if d.exists() {
        d
    } else {
        std::env::temp_dir()
    }
}

fn main() {
    torsk::rng::manual_seed(3);
    let wpath = shm_dir().join(format!("torsk_hogwild_w_{}", std::process::id()));
    let bpath = shm_dir().join(format!("torsk_hogwild_b_{}", std::process::id()));

    // Shared parameters, initialized to zero.
    let shared_w = SharedTensor::create(&wpath, &[DIM], DType::F32).unwrap();
    let shared_b = SharedTensor::create(&bpath, &[1, 1], DType::F32).unwrap();

    let acc0 = accuracy(&shared_w.tensor(), &shared_b.tensor(), 20, 777);
    println!("accuracy before training: {:.1}%", acc0 * 100.0);

    let (wp, bp) = (wpath.clone(), bpath.clone());
    let forked = fork_workers(WORKERS, move |rank| {
        // Each worker maps the same shared parameters...
        let sw = SharedTensor::open(&wp).unwrap();
        let sb = SharedTensor::open(&bp).unwrap();
        let w = sw.tensor(); // zero-copy views
        let b = sb.tensor();
        // ...and pulls one epoch from its own loader: same planted
        // dataset, rank-seeded shuffle, one background prefetch thread
        // (spawned post-fork — children must not inherit parent threads).
        let loader = DataLoader::new(Arc::new(Planted::new(STEPS_PER_WORKER * BATCH, 1)), BATCH)
            .shuffle(true)
            .seed(1000 + rank as u64)
            .workers(1);
        for (x, y) in loader.iter() {
            // Manual forward/backward on a *snapshot-free* read of the
            // shared weights (Hogwild reads may be torn; that's the point).
            let w_col = w.detach().reshape(&[DIM, 1]).requires_grad(true);
            let b_leaf = b.detach().contiguous().requires_grad(true);
            let p = ops::sigmoid(&ops::add(&ops::matmul(&x, &w_col), &b_leaf));
            let loss = ops::bce_loss(&p, &y);
            loss.backward();
            // ...and writes updates straight into shared memory, no locks.
            no_grad(|| {
                w.axpy_(-0.1, &w_col.grad().unwrap().reshape(&[DIM]));
                b.axpy_(-0.1, &b_leaf.grad().unwrap());
            });
        }
    });
    // A dead rank means the shared parameters only saw a fraction of the
    // planned updates — evaluating them anyway would silently bless a
    // partial run. fork_workers names each failed rank (exit status or
    // signal); clean up the shared files, then refuse to continue.
    if let Err(e) = forked {
        shared_w.unlink();
        shared_b.unlink();
        eprintln!("hogwild: aborting, not evaluating a partial run: {e}");
        std::process::exit(1);
    }

    let w = shared_w.tensor();
    let b = shared_b.tensor();
    let acc = accuracy(&w, &b, 20, 777);
    println!(
        "accuracy after {WORKERS} hogwild workers x {STEPS_PER_WORKER} steps: {:.1}%",
        acc * 100.0
    );

    // Learned weights should align with the planted signs.
    let wv = w.to_vec::<f32>();
    let aligned = wv
        .iter()
        .zip(truth().iter())
        .filter(|(l, t)| l.signum() == t.signum())
        .count();
    println!("sign agreement with planted weights: {aligned}/{DIM}");

    shared_w.unlink();
    shared_b.unlink();
    assert!(acc > 0.9, "hogwild training should reach >90% (got {acc})");
    assert!(aligned >= DIM - 2);
    println!("hogwild OK");
}
