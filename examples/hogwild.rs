//! Hogwild! training (§5.4): multiple worker processes updating *shared*
//! model parameters lock-free through `torsk::multiproc` shared-memory
//! tensors — "transparently handles sharing … making it easy to implement
//! techniques like Hogwild".
//!
//! Task: logistic regression on a planted linearly-separable problem.
//! Each of 4 forked workers pulls its own minibatches and applies SGD
//! updates directly into the shared parameter tensors without any locks.
//!
//! Run: `cargo run --release --example hogwild`

use std::path::PathBuf;

use torsk::multiproc::{fork_workers, SharedTensor};
use torsk::prelude::*;
use torsk::rng::Rng;

const DIM: usize = 16;
const WORKERS: usize = 4;
const STEPS_PER_WORKER: usize = 300;
const BATCH: usize = 16;

/// Ground-truth weights used to plant the labels.
fn truth() -> Vec<f32> {
    (0..DIM).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect()
}

fn make_batch(r: &mut Rng) -> (Tensor, Tensor) {
    let w = truth();
    let mut xs = Vec::with_capacity(BATCH * DIM);
    let mut ys = Vec::with_capacity(BATCH);
    for _ in 0..BATCH {
        let x: Vec<f32> = (0..DIM).map(|_| r.normal()).collect();
        let dot: f32 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
        ys.push(if dot > 0.0 { 1.0f32 } else { 0.0 });
        xs.extend(x);
    }
    (Tensor::from_vec(xs, &[BATCH, DIM]), Tensor::from_vec(ys, &[BATCH, 1]))
}

fn accuracy(w: &Tensor, b: &Tensor, n: usize, seed: u64) -> f32 {
    let mut r = Rng::new(seed);
    let mut correct = 0;
    no_grad(|| {
        for _ in 0..n {
            let (x, y) = make_batch(&mut r);
            let p = ops::sigmoid(&ops::add(&ops::matmul(&x, &w.reshape(&[DIM, 1])), b));
            let pv = p.to_vec::<f32>();
            let yv = y.to_vec::<f32>();
            correct += pv.iter().zip(&yv).filter(|(p, y)| (**p > 0.5) == (**y > 0.5)).count();
        }
    });
    correct as f32 / (n * BATCH) as f32
}

fn shm_dir() -> PathBuf {
    let d = PathBuf::from("/dev/shm");
    if d.exists() {
        d
    } else {
        std::env::temp_dir()
    }
}

fn main() {
    torsk::rng::manual_seed(3);
    let wpath = shm_dir().join(format!("torsk_hogwild_w_{}", std::process::id()));
    let bpath = shm_dir().join(format!("torsk_hogwild_b_{}", std::process::id()));

    // Shared parameters, initialized to zero.
    let shared_w = SharedTensor::create(&wpath, &[DIM], DType::F32).unwrap();
    let shared_b = SharedTensor::create(&bpath, &[1, 1], DType::F32).unwrap();

    let acc0 = accuracy(&shared_w.tensor(), &shared_b.tensor(), 20, 777);
    println!("accuracy before training: {:.1}%", acc0 * 100.0);

    let (wp, bp) = (wpath.clone(), bpath.clone());
    fork_workers(WORKERS, move |rank| {
        // Each worker maps the same shared parameters...
        let sw = SharedTensor::open(&wp).unwrap();
        let sb = SharedTensor::open(&bp).unwrap();
        let w = sw.tensor(); // zero-copy views
        let b = sb.tensor();
        let mut r = Rng::new(1000 + rank as u64);
        for _ in 0..STEPS_PER_WORKER {
            let (x, y) = make_batch(&mut r);
            // Manual forward/backward on a *snapshot-free* read of the
            // shared weights (Hogwild reads may be torn; that's the point).
            let w_col = w.detach().reshape(&[DIM, 1]).requires_grad(true);
            let b_leaf = b.detach().contiguous().requires_grad(true);
            let p = ops::sigmoid(&ops::add(&ops::matmul(&x, &w_col), &b_leaf));
            let loss = ops::bce_loss(&p, &y);
            loss.backward();
            // ...and writes updates straight into shared memory, no locks.
            no_grad(|| {
                w.axpy_(-0.1, &w_col.grad().unwrap().reshape(&[DIM]));
                b.axpy_(-0.1, &b_leaf.grad().unwrap());
            });
        }
    })
    .expect("hogwild workers");

    let w = shared_w.tensor();
    let b = shared_b.tensor();
    let acc = accuracy(&w, &b, 20, 777);
    println!("accuracy after {WORKERS} hogwild workers x {STEPS_PER_WORKER} steps: {:.1}%", acc * 100.0);

    // Learned weights should align with the planted signs.
    let wv = w.to_vec::<f32>();
    let aligned = wv
        .iter()
        .zip(truth().iter())
        .filter(|(l, t)| l.signum() == t.signum())
        .count();
    println!("sign agreement with planted weights: {aligned}/{DIM}");

    shared_w.unlink();
    shared_b.unlink();
    assert!(acc > 0.9, "hogwild training should reach >90% (got {acc})");
    assert!(aligned >= DIM - 2);
    println!("hogwild OK");
}
