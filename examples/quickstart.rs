//! Quickstart + end-to-end validation driver.
//!
//! Trains Listing 1's "FullBasicModel" CNN (conv → relu → fc → softmax)
//! on a synthetic 10-class image dataset through the full stack — eager
//! tensors, autograd, DataLoader with parallel workers, SGD — logging the
//! loss curve, then evaluates accuracy and compares against the
//! AOT-compiled static-graph MLP path if artifacts are present.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use torsk::data::{DataLoader, SyntheticImages};
use torsk::nn::{Conv2d, Flatten, Linear, MaxPool2d, Module, ReLU, Sequential};
use torsk::optim::{Optimizer, Sgd};
use torsk::prelude::*;

fn main() {
    torsk::rng::manual_seed(42);

    // ---- Listing 1's model, in Rust -----------------------------------
    let model = Sequential::new()
        .add(Conv2d::new(1, 16, 3, 1, 1))
        .add(ReLU)
        .add(MaxPool2d::new(2, 2))
        .add(Conv2d::new(16, 32, 3, 1, 1))
        .add(ReLU)
        .add(MaxPool2d::new(2, 2))
        .add(Flatten)
        .add(Linear::new(32 * 4 * 4, 64))
        .add(ReLU)
        .add(Linear::new(64, 10));
    println!("model: {} parameters", model.parameters().iter().map(|p| p.numel()).sum::<usize>());

    // Separable synthetic data: class k gets a bump at pixel block k.
    struct Planted {
        base: SyntheticImages,
    }
    impl torsk::data::Dataset for Planted {
        fn len(&self) -> usize {
            self.base.n
        }
        fn get(&self, i: usize) -> (Tensor, Tensor) {
            let (x, y) = self.base.get(i);
            let label = y.item_i64() as usize;
            // Add a strong class-dependent signal.
            let mut v = x.to_vec::<f32>();
            for dy in 0..3 {
                for dx in 0..3 {
                    let row = (label / 5) * 8 + dy + 1;
                    let col = (label % 5) * 3 + dx + 1;
                    v[row * 16 + col] += 4.0;
                }
            }
            (Tensor::from_vec(v, &[1, 16, 16]), y)
        }
    }
    let train = Arc::new(Planted { base: SyntheticImages::new(512, 1, 16, 16, 10) });
    let test = Arc::new(Planted { base: SyntheticImages { seed: 999, ..SyntheticImages::new(256, 1, 16, 16, 10) } });

    let loader = DataLoader::new(train, 32).shuffle(true).workers(2).seed(1);
    let mut opt = Sgd::new(model.parameters(), 0.05).with_momentum(0.9);

    // ---- Training loop: plain Rust control flow ------------------------
    println!("\nepoch  batch  loss");
    for epoch in 0..4 {
        for (i, (x, y)) in loader.iter().enumerate() {
            opt.zero_grad();
            let logits = model.forward(&x);
            let loss = logits.cross_entropy(&y);
            loss.backward();
            opt.step();
            if i % 8 == 0 {
                println!("{epoch:>5}  {i:>5}  {:.4}", loss.item());
            }
        }
    }

    // ---- Evaluation -----------------------------------------------------
    let eval_loader = DataLoader::new(test, 64);
    let mut correct = 0usize;
    let mut total = 0usize;
    no_grad(|| {
        for (x, y) in eval_loader.iter() {
            let logits = model.forward(&x);
            let pred = ops::argmax_dim(&logits, 1);
            let pv = pred.to_vec::<i64>();
            let yv = y.to_vec::<i64>();
            correct += pv.iter().zip(&yv).filter(|(a, b)| a == b).count();
            total += pv.len();
        }
    });
    let acc = correct as f64 / total as f64;
    println!("\ntest accuracy: {:.1}% ({correct}/{total})", 100.0 * acc);
    assert!(acc > 0.9, "planted-signal task should be learnable (got {acc})");

    // ---- Static-graph path (optional, needs `make artifacts`) ----------
    match torsk::graph::run_graph(
        "mlp_step",
        &{
            torsk::rng::manual_seed(7);
            let mut ins = vec![Tensor::randn(&[8, 16]), Tensor::randint(4, &[8])];
            let g = torsk::runtime::Runtime::global().load("mlp_step").unwrap();
            for spec in &g.meta.inputs[2..] {
                ins.push(Tensor::randn(&spec.shape).mul_scalar(0.1));
            }
            ins
        },
    ) {
        Ok(outs) => println!("AOT graph path OK: mlp_step loss = {:.4}", outs[0].item()),
        Err(e) => println!("(AOT graph path skipped: {e})"),
    }

    println!("quickstart OK");
}
