//! Listing 2: generative adversarial training — the paper's showcase for
//! why "rigid APIs would struggle" while define-by-run just works: two
//! models, two optimizers, two losses that reference both models, and a
//! `detach()` in the middle.
//!
//! Task: the generator learns to map N(0,1) noise to a 2-D ring
//! distribution; the discriminator learns to tell ring samples from fakes.
//!
//! Run: `cargo run --release --example gan`

use torsk::nn::{Linear, Module, ReLU, Sequential, Sigmoid, Tanh};
use torsk::optim::{Adam, Optimizer};
use torsk::prelude::*;

fn real_samples(n: usize) -> Tensor {
    // Points on a radius-2 ring with small noise.
    let mut data = Vec::with_capacity(n * 2);
    torsk::rng::with_rng(|r| {
        for _ in 0..n {
            let theta = r.uniform_range(0.0, std::f32::consts::TAU);
            let rad = 2.0 + 0.1 * r.normal();
            data.push(rad * theta.cos());
            data.push(rad * theta.sin());
        }
    });
    Tensor::from_vec(data, &[n, 2])
}

fn get_noise(n: usize, dim: usize) -> Tensor {
    Tensor::randn(&[n, dim])
}

fn main() {
    torsk::rng::manual_seed(7);
    let noise_dim = 8;
    let batch = 64;

    // create_generator() / create_discriminator()
    let generator = Sequential::new()
        .add(Linear::new(noise_dim, 32))
        .add(ReLU)
        .add(Linear::new(32, 32))
        .add(ReLU)
        .add(Linear::new(32, 2))
        .add(Tanh); // bounded raw output, scaled below
    let discriminator = Sequential::new()
        .add(Linear::new(2, 32))
        .add(ReLU)
        .add(Linear::new(32, 16))
        .add(ReLU)
        .add(Linear::new(16, 1))
        .add(Sigmoid);

    let mut opt_d = Adam::new(discriminator.parameters(), 2e-3);
    let mut opt_g = Adam::new(generator.parameters(), 2e-3);

    let gen_forward = |noise: &Tensor| generator.forward(noise).mul_scalar(3.0);

    println!("step   errD     errG     D(real)  D(fake)");
    let mut last = (0.0, 0.0, 0.0, 0.0);
    for step in 0..400 {
        // ---- (1) Update discriminator -------------------------------
        opt_d.zero_grad();
        let real = real_samples(batch);
        let real_label = Tensor::ones(&[batch, 1]);
        let fake_label = Tensor::zeros(&[batch, 1]);

        let d_real = discriminator.forward(&real);
        let err_d_real = ops::bce_loss(&d_real, &real_label);
        err_d_real.backward();

        let fake = gen_forward(&get_noise(batch, noise_dim));
        // The paper's detach(): keep G out of D's backward pass.
        let d_fake = discriminator.forward(&fake.detach());
        let err_d_fake = ops::bce_loss(&d_fake, &fake_label);
        err_d_fake.backward();
        opt_d.step();

        // ---- (2) Update generator -----------------------------------
        opt_g.zero_grad();
        let d_fake_for_g = discriminator.forward(&fake);
        let err_g = ops::bce_loss(&d_fake_for_g, &real_label);
        err_g.backward();
        opt_g.step();

        last = (
            err_d_real.item() + err_d_fake.item(),
            err_g.item(),
            d_real.mean().item(),
            d_fake_for_g.mean().item(),
        );
        if step % 50 == 0 {
            println!("{step:>4}   {:.4}   {:.4}   {:.3}    {:.3}", last.0, last.1, last.2, last.3);
        }
    }

    // Convergence check: generated samples should land near the ring.
    let samples = no_grad(|| gen_forward(&get_noise(512, noise_dim)));
    let v = samples.to_vec::<f32>();
    let mean_radius: f32 =
        v.chunks(2).map(|p| (p[0] * p[0] + p[1] * p[1]).sqrt()).sum::<f32>() / 512.0;
    println!("\nmean generated radius: {mean_radius:.3} (target 2.0)");
    assert!(
        (1.0..3.0).contains(&mean_radius),
        "generator should approach the ring (got {mean_radius})"
    );
    // Discriminator should be near-confused on fakes by now.
    assert!(last.3 > 0.2, "D(fake) should rise toward 0.5, got {}", last.3);
    println!("gan OK");
}
