//! Listing 2: generative adversarial training — the paper's showcase for
//! why "rigid APIs would struggle" while define-by-run just works: two
//! models, two optimizers, two losses that reference both models, and a
//! `detach()` in the middle.
//!
//! Task: the generator learns to map N(0,1) noise to a 2-D ring
//! distribution; the discriminator learns to tell ring samples from fakes.
//! Real samples come from a `Dataset` + prefetching `DataLoader` (two
//! background workers) instead of a hand-rolled per-step `Vec` loop, so
//! the real-batch stream is seed-deterministic and its buffers are reused
//! from the caching allocator across steps.
//!
//! Run: `cargo run --release --example gan`

use std::sync::Arc;

use torsk::alloc::Allocator;
use torsk::data::{DataLoader, Dataset};
use torsk::nn::{Linear, Module, ReLU, Sequential, Sigmoid, Tanh};
use torsk::optim::{Adam, Optimizer};
use torsk::prelude::*;
use torsk::rng::Rng;

/// Points on a radius-2 ring with small noise, deterministic per index.
struct RingDataset {
    n: usize,
    seed: u64,
}

impl Dataset for RingDataset {
    fn len(&self) -> usize {
        self.n
    }

    fn get(&self, index: usize) -> (Tensor, Tensor) {
        let mut r = Rng::for_index(self.seed, index as u64);
        let theta = r.uniform_range(0.0, std::f32::consts::TAU);
        let rad = 2.0 + 0.1 * r.normal();
        (
            Tensor::from_vec(vec![rad * theta.cos(), rad * theta.sin()], &[2]),
            // The "real" label — batches arrive training-ready.
            Tensor::from_vec(vec![1.0f32], &[1]),
        )
    }
}

fn get_noise(n: usize, dim: usize) -> Tensor {
    Tensor::randn(&[n, dim])
}

fn main() {
    torsk::rng::manual_seed(7);
    let noise_dim = 8;
    let batch = 64;

    // create_generator() / create_discriminator()
    let generator = Sequential::new()
        .add(Linear::new(noise_dim, 32))
        .add(ReLU)
        .add(Linear::new(32, 32))
        .add(ReLU)
        .add(Linear::new(32, 2))
        .add(Tanh); // bounded raw output, scaled below
    let discriminator = Sequential::new()
        .add(Linear::new(2, 32))
        .add(ReLU)
        .add(Linear::new(32, 16))
        .add(ReLU)
        .add(Linear::new(16, 1))
        .add(Sigmoid);

    let mut opt_d = Adam::new(discriminator.parameters(), 2e-3);
    let mut opt_g = Adam::new(generator.parameters(), 2e-3);

    let gen_forward = |noise: &Tensor| generator.forward(noise).mul_scalar(3.0);

    // Real data: 4096 ring points, reshuffled every epoch from one seed,
    // prefetched by two workers while the GAN steps run.
    let real_loader = DataLoader::new(Arc::new(RingDataset { n: 4096, seed: 99 }), batch)
        .shuffle(true)
        .seed(7)
        .drop_last(true)
        .workers(2);

    println!("step   errD     errG     D(real)  D(fake)");
    let mut last = (0.0, 0.0, 0.0, 0.0);
    let mut step = 0;
    'train: loop {
        for (real, real_label) in real_loader.iter() {
            if step >= 400 {
                break 'train;
            }
            let fake_label = Tensor::zeros(&[batch, 1]);

            // ---- (1) Update discriminator ---------------------------
            opt_d.zero_grad();
            let d_real = discriminator.forward(&real);
            let err_d_real = ops::bce_loss(&d_real, &real_label);
            err_d_real.backward();

            let fake = gen_forward(&get_noise(batch, noise_dim));
            // The paper's detach(): keep G out of D's backward pass.
            let d_fake = discriminator.forward(&fake.detach());
            let err_d_fake = ops::bce_loss(&d_fake, &fake_label);
            err_d_fake.backward();
            opt_d.step();

            // ---- (2) Update generator -------------------------------
            opt_g.zero_grad();
            let d_fake_for_g = discriminator.forward(&fake);
            let err_g = ops::bce_loss(&d_fake_for_g, &real_label);
            err_g.backward();
            opt_g.step();

            last = (
                err_d_real.item() + err_d_fake.item(),
                err_g.item(),
                d_real.mean().item(),
                d_fake_for_g.mean().item(),
            );
            if step % 50 == 0 {
                println!(
                    "{step:>4}   {:.4}   {:.4}   {:.3}    {:.3}",
                    last.0, last.1, last.2, last.3
                );
            }
            step += 1;
        }
    }

    // Steady-state real batches must come from the allocator cache — the
    // old hand-rolled loop allocated a fresh Vec per step instead. One
    // epoch of *pure loading* after training isolates the loader's
    // allocator traffic from the GAN's activations and gradients.
    let host = torsk::ctx::host_allocator();
    let (h0, l0) = (host.stats(), real_loader.stats());
    for (x, _) in real_loader.iter() {
        std::hint::black_box(&x);
    }
    let hd = host.stats().delta(&h0);
    let ld = real_loader.stats().delta(&l0);
    let rate = hd.cache_hit_rate();
    println!(
        "\nloader: {} real batches, stall {:.2} ms, steady-state buffers {:.0}% from cache",
        ld.batches,
        ld.stall_ns as f64 / 1e6,
        rate * 100.0
    );
    assert!(
        rate > 0.5,
        "steady-state real batches should hit the buffer cache (rate {rate:.3}, hits {}, \
         driver allocs {})",
        hd.cache_hits,
        hd.driver_allocs
    );

    // Convergence check: generated samples should land near the ring.
    let samples = no_grad(|| gen_forward(&get_noise(512, noise_dim)));
    let v = samples.to_vec::<f32>();
    let mean_radius: f32 =
        v.chunks(2).map(|p| (p[0] * p[0] + p[1] * p[1]).sqrt()).sum::<f32>() / 512.0;
    println!("\nmean generated radius: {mean_radius:.3} (target 2.0)");
    assert!(
        (1.0..3.0).contains(&mean_radius),
        "generator should approach the ring (got {mean_radius})"
    );
    // Discriminator should be near-confused on fakes by now.
    assert!(last.3 > 0.2, "D(fake) should rise toward 0.5, got {}", last.3);
    println!("gan OK");
}
