//! Kill-and-resume training with `torsk::serialize` (ARCHITECTURE.md §7).
//!
//! Trains a small regression MLP for 3 epochs, then simulates a crash:
//! the run is killed mid-epoch-1 right after saving a checkpoint, every
//! in-memory object is dropped, and a "new process" rebuilds the model,
//! optimizer, and loader from scratch, restores them from the checkpoint
//! file, and finishes the run. The resumed parameters are compared
//! **bitwise** against an uninterrupted reference run — the same pin
//! `tests/chaos.rs` enforces in CI.
//!
//! Run: `cargo run --release --example checkpoint_resume`

use std::sync::Arc;

use torsk::data::{DataLoader, Dataset};
use torsk::optim::Adam;
use torsk::prelude::*;
use torsk::rng::Rng;
use torsk::serialize::{Checkpoint, LoaderState};

const IN: usize = 8;
const OUT: usize = 4;
const N: usize = 128;
const BATCH: usize = 16;
const EPOCHS: usize = 3;
const KILL_AT: (usize, usize) = (1, 4); // crash after batch 4 of epoch 1

/// Deterministic per-index regression pairs: any worker, any order, the
/// same bytes.
struct Synth;

impl Dataset for Synth {
    fn len(&self) -> usize {
        N
    }

    fn get(&self, index: usize) -> (Tensor, Tensor) {
        let mut r = Rng::for_index(0xC0FFEE, index as u64);
        let x: Vec<f32> = (0..IN).map(|_| r.normal()).collect();
        let y: Vec<f32> = (0..OUT).map(|_| r.normal()).collect();
        (Tensor::from_vec(x, &[IN]), Tensor::from_vec(y, &[OUT]))
    }
}

fn build() -> (nn::Sequential, Adam, DataLoader) {
    let model = nn::Sequential::new()
        .add(nn::Linear::new(IN, 32))
        .add(nn::ReLU)
        .add(nn::Linear::new(32, OUT));
    let opt = Adam::new(model.parameters(), 1e-2);
    let loader = DataLoader::new(Arc::new(Synth), BATCH).shuffle(true).seed(17).workers(2);
    (model, opt, loader)
}

fn train_step(model: &nn::Sequential, opt: &mut Adam, x: &Tensor, y: &Tensor) -> f32 {
    opt.zero_grad();
    let loss = model.forward(x).mse_loss(y);
    loss.backward();
    opt.step();
    loss.to_vec::<f32>()[0]
}

fn param_bits(model: &nn::Sequential) -> Vec<u32> {
    model
        .state_dict()
        .values()
        .flat_map(|t| t.to_vec::<f32>().into_iter().map(f32::to_bits))
        .collect()
}

fn main() {
    let ckpt_path =
        std::env::temp_dir().join(format!("torsk_resume_{}.ckpt", std::process::id()));

    // ---- Reference: 3 uninterrupted epochs. ----
    torsk::rng::manual_seed(42);
    let (model, mut opt, loader) = build();
    let mut last = 0.0;
    for _ in 0..EPOCHS {
        for (x, y) in loader.iter() {
            last = train_step(&model, &mut opt, &x, &y);
        }
    }
    let expected = param_bits(&model);
    println!("uninterrupted run: final loss {last:.6}");

    // ---- Interrupted run, identical init. ----
    torsk::rng::manual_seed(42);
    let (model, mut opt, loader) = build();
    for (x, y) in loader.iter() {
        train_step(&model, &mut opt, &x, &y); // epoch 0
    }
    {
        let mut epoch1 = loader.iter();
        for _ in 0..KILL_AT.1 {
            let (x, y) = epoch1.next().expect("epoch is longer than the kill point");
            train_step(&model, &mut opt, &x, &y);
        }
        Checkpoint::new(model.state_dict())
            .with_optimizer(&opt)
            .with_loader(LoaderState {
                seed: loader.seed_value(),
                epoch: KILL_AT.0 as u64,
                next_batch: KILL_AT.1 as u64,
            })
            .save(&ckpt_path)
            .expect("save checkpoint");
        println!("checkpoint saved at epoch {} batch {}; crashing now", KILL_AT.0, KILL_AT.1);
        // The iterator dies here mid-epoch: its workers are shut down and
        // joined, exactly as a crash + supervisor restart would leave us.
    }
    drop((model, opt, loader));

    // ---- "New process": restore everything from the file. ----
    let ck = Checkpoint::load(&ckpt_path).expect("load checkpoint");
    torsk::rng::manual_seed(ck.global_seed);
    let (model, mut opt, loader) = build();
    model.load_state_dict(&ck.model);
    opt.load_state_dict(ck.optim.as_ref().expect("checkpoint carries optimizer state"));
    let ls = ck.loader.expect("checkpoint carries the loader coordinate");
    assert_eq!(ls.seed, loader.seed_value(), "loader must be rebuilt with the saved seed");
    loader.resume(ls.epoch as usize, ls.next_batch as usize);
    for (x, y) in loader.iter() {
        last = train_step(&model, &mut opt, &x, &y); // rest of epoch 1
    }
    for (x, y) in loader.iter() {
        last = train_step(&model, &mut opt, &x, &y); // epoch 2
    }
    println!("resumed run:       final loss {last:.6}");

    assert_eq!(param_bits(&model), expected, "resume must be bitwise identical");
    std::fs::remove_file(&ckpt_path).ok();
    println!("resumed parameters are bitwise identical to the uninterrupted run — OK");
}
