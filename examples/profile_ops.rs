use torsk::models::{BenchModel, ResNet50, Vgg19};
use torsk::profiler::{self, Track};
use std::collections::HashMap;

fn main() {
    torsk::rng::manual_seed(0);
    let which = std::env::args().nth(1).unwrap_or_else(|| "resnet".into());
    let model: Box<dyn BenchModel> = if which == "vgg" {
        Box::new(Vgg19::new(3, 32, 10, 16))
    } else {
        Box::new(ResNet50::new(3, 32, 10, 16))
    };
    let batch = model.make_batch(0);
    // Warmup
    model.loss(&batch).backward();
    for p in model.parameters() { p.set_grad(None); }

    let t0 = std::time::Instant::now();
    profiler::start();
    let loss = model.loss(&batch);
    let t_fwd = t0.elapsed();
    loss.backward();
    let t_tot = t0.elapsed();
    let events = profiler::stop();
    println!("forward: {:?}  backward: {:?}", t_fwd, t_tot - t_fwd);

    let mut agg: HashMap<String, (u64, usize)> = HashMap::new();
    for e in &events {
        if e.track == Track::Host {
            let entry = agg.entry(e.name.clone()).or_default();
            entry.0 += e.dur_ns();
            entry.1 += 1;
        }
    }
    let mut rows: Vec<_> = agg.into_iter().collect();
    rows.sort_by_key(|(_, (ns, _))| std::cmp::Reverse(*ns));
    println!("{:<24} {:>10} {:>8}", "op", "total ms", "count");
    for (name, (ns, count)) in rows.iter().take(20) {
        println!("{:<24} {:>10.1} {:>8}", name, *ns as f64 / 1e6, count);
    }
}
