//! Deliberate invariant violations for pallas-audit's negative tests.
//!
//! This file is PARSED by the audit library's integration tests — it is
//! never compiled, so the unresolved names (`Tensor`, `Registry`, ...)
//! are fine. Every section below must keep firing its lint; the trailing
//! "clean" section must keep NOT firing. If you edit this file, update
//! the expected counts in `tests/lints.rs`.
#![allow(dead_code)]

use std::collections::HashMap;
use std::time::Instant;

// Hidden materialization in a contractually copy-free path.
pub fn hidden_copy(t: &Tensor) -> Tensor {
    t.contiguous()
}

// Unordered iteration feeding an accumulation: result depends on hash
// order.
pub fn unordered_sum(m: &HashMap<String, f32>) -> f32 {
    let mut total = 0.0;
    for (_k, v) in m.iter() {
        total += v;
    }
    total
}

// Timing-dependent control flow in a kernel path.
pub fn timed_cutoff() -> bool {
    Instant::now().elapsed().as_nanos() % 2 == 0
}

// Ad-hoc threads instead of kernels::parallel_for.
pub fn rogue_threads() {
    std::thread::spawn(|| {});
    let _ = std::thread::Builder::new().name("rogue".into()).spawn(|| {});
}

// An unsafe block with no justification anywhere near it.
pub fn unjustified_write(p: *mut f32) {
    unsafe {
        *p = 1.0;
    }
}

// An unsafe fn carrying no doc section and no justifying comment.
// (Keep this comment free of the S-word marker, or it would satisfy
// the lint's proximity window by accident.)
pub unsafe fn undocumented_read(p: *const f32) -> f32 {
    *p
}

// Registrations that dodge the OpInfo gradcheck suite.
pub fn sampleless_registrations(reg: &mut Registry) {
    reg.add(OpDef::new("fixture:bad", 1, 1, &[]).kernel_all(k_bad));
    register_op(OpDef::new("fixture:bad2", 1, 1, &[]).kernel_all(k_bad));
}

// A graph-cache guard key built from tensor *data*: fires `no-data-hash`
// when this file is scanned under dispatch/capture/ (capture guards must
// key on shapes/dtypes/strides only).
pub fn poisoned_guard_key(t: &Tensor) -> String {
    format!("{:?}", t.to_vec())
}

// ---------------------------------------------------------------------
// Clean section: none of the following may be flagged.
// ---------------------------------------------------------------------

pub fn justified_write(p: *mut f32) {
    // SAFETY: caller hands an exclusive, in-bounds pointer.
    unsafe {
        *p = 2.0;
    }
}

/// Reads one element.
///
/// # Safety
/// `p` must be valid for reads.
pub unsafe fn documented_read(p: *const f32) -> f32 {
    *p
}

pub fn sampled_registration(reg: &mut Registry) {
    reg.add(OpDef::new("fixture:good", 1, 1, &[]).kernel_all(k_good).sample_inputs(s_good));
    register_op(OpDef::new("fixture:good2", 1, 1, &[]).sample_inputs(s_good));
}

// A counter `.add(..)` is not a registration; nothing to chain.
pub fn counter_add(c: &AtomicU64) {
    c.add(1);
}

// A metadata-only key builder, and a data read outside any key/guard
// function: both legal everywhere, including dispatch/capture/.
pub fn honest_guard_key(t: &Tensor) -> String {
    format!("{:?}|{:?}|{:?}", t.shape(), t.dtype(), t.strides())
}

pub fn replay_reads_data(t: &Tensor) -> Vec<f32> {
    t.to_vec()
}

#[cfg(test)]
mod tests {
    // Test modules may violate invariants on purpose (should_panic
    // negatives); the walker must skip this entire block.
    fn deliberate_negatives(reg: &mut Registry, p: *mut f32) {
        reg.add(OpDef::new("fixture:test_only", 1, 1, &[]));
        unsafe {
            *p = 3.0;
        }
    }
}
