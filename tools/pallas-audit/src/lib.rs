//! `pallas-audit` — a custom static-analysis pass over `rust/src`.
//!
//! The torsk runtime is a hand-built unsafe parallel system: ~100 `unsafe`
//! sites whose soundness rests on documented invariants (disjoint write
//! ranges, out-aliases-input only on the Fast plan, determinism-safe
//! iteration order). This crate machine-checks the *source-level* half of
//! those invariants; the `debug-checks` feature of the `torsk` crate
//! checks the runtime half. Six lints:
//!
//! | lint | scope | rule |
//! |------|-------|------|
//! | `safety-comment`   | all of `rust/src`          | every `unsafe` keyword carries a nearby `// SAFETY:` justification (or a `# Safety` doc section) |
//! | `no-contiguous`    | `dispatch/linalg.rs`, `kernels/` | no `.contiguous()` calls — the GEMM paths are contractually copy-free (generalizes the old `include_str!` source pin in `tests/gemm_parity.rs`) |
//! | `no-raw-spawn`     | all but `kernels/mod.rs`, `multiproc/` | no `std::thread::spawn` / `thread::Builder` — parallelism goes through `kernels::parallel_for` or the multiproc layer |
//! | `determinism`      | `kernels/`, `dispatch/` (incl. `dispatch/capture/`) | no `HashMap`/`HashSet` (iteration-order hazard), `Instant`/`SystemTime` (timing-dependent control flow), ad-hoc RNG, or per-call CPU-feature probes (`is_x86_feature_detected!`/CPUID — the one cached-at-init site in `kernels/simd.rs` is allowlisted) in kernel/dispatch code paths |
//! | `opinfo-samples`   | all of `rust/src`          | every inline `Registry::add` / `register_op` call chains `.sample_inputs(..)` so no op dodges the OpInfo gradcheck suite |
//! | `no-data-hash`     | `dispatch/capture/`        | graph-cache key/guard builders (`fn` names containing `key` or `guard`) never read tensor *data* (`.to_vec`, `.item`, `.data_ptr`, `.as_slice`, `.storage`) — capture guards key on shapes/dtypes/strides only, so a data read is either a correctness bug (stale hit on changed values) or an O(numel) hash on the hot path |
//!
//! Mechanics: each file is parsed with `syn` (so comments, strings and
//! doc text can never false-positive); AST-shaped rules run as a
//! `syn::visit` pass, and keyword/ident rules (`unsafe`, `HashMap`, ...)
//! run over the parsed token stream, which also reaches into
//! `macro_rules!` bodies that the typed AST hides. `#[cfg(test)]` modules
//! are excluded: negative tests *deliberately* violate invariants
//! (should_panic registrations), and test code is exercised by the
//! compiler, Miri and TSan instead.
//!
//! Intentional exceptions live in per-lint allowlist files
//! (`tools/pallas-audit/allow/<lint>.allow`, one `path — justification`
//! line each). The pass emits a machine-readable report
//! (`torsk.pallas_audit.v1` JSON) and exits non-zero on any violation not
//! covered by an allowlist entry.

use std::collections::BTreeMap;
use std::path::Path;

use proc_macro2::{TokenStream, TokenTree};
use quote::ToTokens;
use syn::spanned::Spanned;
use syn::visit::{self, Visit};

/// Lint identifiers, in report order.
pub const LINTS: &[&str] = &[
    "safety-comment",
    "no-contiguous",
    "no-raw-spawn",
    "determinism",
    "opinfo-samples",
    "no-data-hash",
];

/// How far (in source lines) a `SAFETY` justification may sit from the
/// `unsafe` keyword it covers: up to [`SAFETY_WINDOW_ABOVE`] lines above
/// (comment block, possibly separated by attributes) or
/// [`SAFETY_WINDOW_BELOW`] lines below (first lines inside the block).
pub const SAFETY_WINDOW_ABOVE: usize = 6;
pub const SAFETY_WINDOW_BELOW: usize = 2;

/// One finding: a lint, a location, and what the walker saw there.
#[derive(Debug, Clone)]
pub struct Violation {
    pub lint: &'static str,
    /// Path relative to the audited root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    pub message: String,
    /// `Some(justification)` when an allowlist entry covers this finding.
    pub allowed: Option<String>,
}

// ---------------------------------------------------------------------
// Lint scoping
// ---------------------------------------------------------------------

/// Per-file lint scope, derived from the path relative to `rust/src`.
#[derive(Debug, Clone, Copy)]
pub struct Scope {
    pub contiguous: bool,
    pub spawn: bool,
    pub determinism: bool,
    pub data_hash: bool,
}

impl Scope {
    /// The scope for a source file at `rel` (e.g. `dispatch/linalg.rs`).
    pub fn for_path(rel: &str) -> Scope {
        let in_kernels = rel.starts_with("kernels/") || rel == "kernels.rs";
        let in_dispatch = rel.starts_with("dispatch/") || rel == "dispatch.rs";
        Scope {
            // The GEMM paths are contractually copy-free: a `.contiguous()`
            // there is a silent materialization (the bug class the old
            // include_str! pin guarded against, now for every kernel file).
            contiguous: rel == "dispatch/linalg.rs" || in_kernels,
            // The only sanctioned thread sources are the kernel pool and
            // the multiproc layer (fork-based, own safety contract).
            spawn: !(rel == "kernels/mod.rs" || rel.starts_with("multiproc/")),
            // The dispatch/ prefix deliberately includes dispatch/capture/:
            // graph tracing, compilation and replay are dispatch-path code.
            determinism: in_kernels || in_dispatch,
            // Graph-capture guard keys must be O(rank), data-independent.
            data_hash: rel.starts_with("dispatch/capture/"),
        }
    }
}

// ---------------------------------------------------------------------
// Per-file audit
// ---------------------------------------------------------------------

/// Audit one source file. `rel` selects the lint scope (see
/// [`Scope::for_path`]); parse failures surface as `Err`.
pub fn audit_source(rel: &str, src: &str) -> Result<Vec<Violation>, String> {
    let file = syn::parse_file(src).map_err(|e| format!("{rel}: parse error: {e}"))?;
    let lines: Vec<&str> = src.lines().collect();
    let scope = Scope::for_path(rel);

    let mut w = Walker {
        rel,
        scope,
        out: Vec::new(),
        test_ranges: Vec::new(),
        keyed_fn_depth: 0,
    };
    w.visit_file(&file);

    // Token-level rules: the `unsafe` keyword and determinism-hazard
    // idents, found wherever they appear — including `macro_rules!`
    // bodies, which the typed AST exposes only as raw tokens.
    let mut token_hits: Vec<(usize, &'static str, String)> = Vec::new();
    scan_tokens(&file.to_token_stream(), scope, &mut token_hits);
    for (line, lint, message) in token_hits {
        if lint == "safety-comment" && has_safety_near(&lines, line) {
            continue;
        }
        w.out.push(Violation { lint, file: rel.to_string(), line, message, allowed: None });
    }

    // Drop findings inside #[cfg(test)] modules: negative tests violate
    // the invariants on purpose.
    let ranges = w.test_ranges;
    let mut out = w.out;
    out.retain(|v| !ranges.iter().any(|&(s, e)| v.line >= s && v.line <= e));
    out.sort_by(|a, b| (a.line, a.lint).cmp(&(b.line, b.lint)));
    Ok(out)
}

/// Is there a `SAFETY` justification near source line `line` (1-based)?
/// Accepts `// SAFETY: ...` comments and `# Safety` doc sections,
/// case-insensitively, within the configured window. The colon / heading
/// marker is required: a mere identifier containing "safety" (a function
/// name, a test name) never satisfies the lint.
fn has_safety_near(lines: &[&str], line: usize) -> bool {
    let lo = line.saturating_sub(SAFETY_WINDOW_ABOVE + 1); // 0-based index
    let hi = (line + SAFETY_WINDOW_BELOW).min(lines.len());
    lines[lo..hi].iter().any(|l| {
        let l = l.to_ascii_lowercase();
        l.contains("safety:") || l.contains("# safety")
    })
}

/// Recursively scan a token stream for keyword/ident-level lint hits.
fn scan_tokens(ts: &TokenStream, scope: Scope, out: &mut Vec<(usize, &'static str, String)>) {
    for tt in ts.clone() {
        match tt {
            TokenTree::Ident(id) => {
                let line = id.span().start().line;
                let name = id.to_string();
                match name.as_str() {
                    "unsafe" => out.push((
                        line,
                        "safety-comment",
                        "`unsafe` without a nearby `// SAFETY:` justification".to_string(),
                    )),
                    "HashMap" | "HashSet" if scope.determinism => out.push((
                        line,
                        "determinism",
                        format!("`{name}` in a kernel/dispatch path (iteration order is unordered)"),
                    )),
                    "Instant" | "SystemTime" if scope.determinism => out.push((
                        line,
                        "determinism",
                        format!("`{name}` in a kernel/dispatch path (timing-dependent behavior)"),
                    )),
                    "thread_rng" | "ThreadRng" | "RandomState" if scope.determinism => out.push((
                        line,
                        "determinism",
                        format!("ad-hoc RNG `{name}` in a kernel/dispatch path (use crate::rng)"),
                    )),
                    "is_x86_feature_detected" | "is_aarch64_feature_detected" | "__cpuid"
                    | "__cpuid_count"
                        if scope.determinism =>
                    {
                        out.push((
                            line,
                            "determinism",
                            format!(
                                "CPU-feature probe `{name}` in a kernel/dispatch path — \
                                 detection must happen once, at the cached init site in \
                                 kernels/simd.rs"
                            ),
                        ))
                    }
                    _ => {}
                }
            }
            TokenTree::Group(g) => scan_tokens(&g.stream(), scope, out),
            _ => {}
        }
    }
}

struct Walker<'a> {
    rel: &'a str,
    scope: Scope,
    out: Vec<Violation>,
    /// (start, end) line ranges of `#[cfg(test)]` modules.
    test_ranges: Vec<(usize, usize)>,
    /// >0 while visiting the body of a cache-key/guard builder (a `fn`
    /// whose name contains `key` or `guard`) in `data_hash` scope.
    keyed_fn_depth: usize,
}

/// Is `name` a cache-key/guard builder the `no-data-hash` lint covers?
fn is_keyed_fn_name(name: &str) -> bool {
    let n = name.to_ascii_lowercase();
    n.contains("key") || n.contains("guard")
}

/// Tensor methods that read element data — forbidden in key builders.
const DATA_READS: &[&str] = &["to_vec", "item", "data_ptr", "as_slice", "storage"];

impl Walker<'_> {
    fn push(&mut self, lint: &'static str, line: usize, message: String) {
        self.out.push(Violation { lint, file: self.rel.to_string(), line, message, allowed: None });
    }

    /// Does an argument list that builds an `OpDef` also chain
    /// `.sample_inputs(..)`? Token containment is enough: registration is
    /// written inline throughout the codebase, and the runtime assert in
    /// `Registry::add` backstops anything assembled indirectly.
    fn check_registration(&mut self, line: usize, what: &str, args_tokens: &str) {
        if args_tokens.contains("OpDef") && !args_tokens.contains("sample_inputs") {
            self.push(
                "opinfo-samples",
                line,
                format!("{what} builds an OpDef without chaining .sample_inputs(..)"),
            );
        }
    }
}

fn path_segments(p: &syn::Path) -> Vec<String> {
    p.segments.iter().map(|s| s.ident.to_string()).collect()
}

fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        a.path().is_ident("cfg") && a.to_token_stream().to_string().contains("test")
    })
}

impl<'ast> Visit<'ast> for Walker<'_> {
    fn visit_item_mod(&mut self, node: &'ast syn::ItemMod) {
        if is_cfg_test(&node.attrs) {
            let span = node.span();
            self.test_ranges.push((span.start().line, span.end().line));
            return; // nothing inside a test module is audited
        }
        visit::visit_item_mod(self, node);
    }

    fn visit_item_fn(&mut self, node: &'ast syn::ItemFn) {
        let keyed = self.scope.data_hash && is_keyed_fn_name(&node.sig.ident.to_string());
        if keyed {
            self.keyed_fn_depth += 1;
        }
        visit::visit_item_fn(self, node);
        if keyed {
            self.keyed_fn_depth -= 1;
        }
    }

    fn visit_impl_item_fn(&mut self, node: &'ast syn::ImplItemFn) {
        let keyed = self.scope.data_hash && is_keyed_fn_name(&node.sig.ident.to_string());
        if keyed {
            self.keyed_fn_depth += 1;
        }
        visit::visit_impl_item_fn(self, node);
        if keyed {
            self.keyed_fn_depth -= 1;
        }
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        let method = node.method.to_string();
        let line = node.method.span().start().line;
        if self.keyed_fn_depth > 0 && DATA_READS.contains(&method.as_str()) {
            self.push(
                "no-data-hash",
                line,
                format!(
                    ".{method}() reads tensor data inside a cache-key/guard builder — \
                     capture keys hash shapes/dtypes/strides only"
                ),
            );
        }
        match method.as_str() {
            "contiguous" if self.scope.contiguous && node.args.is_empty() => self.push(
                "no-contiguous",
                line,
                ".contiguous() in a contractually copy-free GEMM/kernel path".to_string(),
            ),
            "spawn" if self.scope.spawn => {
                let recv = node.receiver.to_token_stream().to_string();
                if recv.contains("Builder") || recv.contains("thread") {
                    self.push(
                        "no-raw-spawn",
                        line,
                        "thread spawned outside kernels::parallel_for / multiproc".to_string(),
                    );
                }
            }
            "add" => {
                let args = node.args.to_token_stream().to_string();
                self.check_registration(line, "Registry::add", &args);
            }
            _ => {}
        }
        visit::visit_expr_method_call(self, node);
    }

    fn visit_expr_call(&mut self, node: &'ast syn::ExprCall) {
        if let syn::Expr::Path(p) = &*node.func {
            let segs = path_segments(&p.path);
            let line = p.span().start().line;
            if let Some(last) = segs.last() {
                if last == "spawn"
                    && segs.iter().any(|s| s == "thread")
                    && self.scope.spawn
                {
                    self.push(
                        "no-raw-spawn",
                        line,
                        "std::thread::spawn outside kernels::parallel_for / multiproc".to_string(),
                    );
                }
                if last == "register_op" {
                    let args = node.args.to_token_stream().to_string();
                    self.check_registration(line, "register_op", &args);
                }
            }
        }
        visit::visit_expr_call(self, node);
    }
}

// ---------------------------------------------------------------------
// Tree walk
// ---------------------------------------------------------------------

/// Recursively collect `.rs` files under `root`, sorted for a
/// deterministic report order.
pub fn rust_files(root: &Path) -> std::io::Result<Vec<std::path::PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Audit every `.rs` file under `root`. Parse failures become hard
/// errors: an unparseable source tree cannot be certified.
pub fn audit_tree(root: &Path) -> Result<Vec<Violation>, String> {
    let files = rust_files(root).map_err(|e| format!("walking {}: {e}", root.display()))?;
    let mut out = Vec::new();
    for path in files {
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        out.extend(audit_source(&rel, &src)?);
    }
    out.sort_by(|a, b| (a.file.as_str(), a.line, a.lint).cmp(&(b.file.as_str(), b.line, b.lint)));
    Ok(out)
}

// ---------------------------------------------------------------------
// Allowlists
// ---------------------------------------------------------------------

/// One intentional exception: a path (file, or `dir/` prefix) plus its
/// one-line justification.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub path: String,
    pub justification: String,
    pub used: bool,
}

/// Load `allow/<lint>.allow` files from `dir`. Missing files mean "no
/// exceptions for that lint". Entry lines are
/// `path — justification` (an `--` separator works too); `#` comments and
/// blank lines are skipped.
pub fn load_allowlists(dir: &Path) -> Result<BTreeMap<&'static str, Vec<AllowEntry>>, String> {
    let mut map = BTreeMap::new();
    for &lint in LINTS {
        let path = dir.join(format!("{}.allow", lint.replace('-', "_")));
        let mut entries = Vec::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            for (i, raw) in text.lines().enumerate() {
                let line = raw.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                let (p, j) = match line.split_once("—").or_else(|| line.split_once("--")) {
                    Some((p, j)) => (p.trim(), j.trim()),
                    None => {
                        return Err(format!(
                            "{}:{}: allowlist entry needs `path — justification`",
                            path.display(),
                            i + 1
                        ))
                    }
                };
                if j.is_empty() {
                    return Err(format!(
                        "{}:{}: empty justification for '{p}'",
                        path.display(),
                        i + 1
                    ));
                }
                entries.push(AllowEntry {
                    path: p.to_string(),
                    justification: j.to_string(),
                    used: false,
                });
            }
        }
        map.insert(lint, entries);
    }
    Ok(map)
}

/// Mark violations covered by allowlist entries (exact file match, or a
/// `dir/` prefix entry). Returns the list of entries that matched
/// nothing — allowlist rot worth surfacing.
pub fn apply_allowlists(
    violations: &mut [Violation],
    allow: &mut BTreeMap<&'static str, Vec<AllowEntry>>,
) -> Vec<(String, String)> {
    for v in violations.iter_mut() {
        if let Some(entries) = allow.get_mut(v.lint) {
            for e in entries.iter_mut() {
                let hit = v.file == e.path
                    || (e.path.ends_with('/') && v.file.starts_with(e.path.as_str()));
                if hit {
                    v.allowed = Some(e.justification.clone());
                    e.used = true;
                    break;
                }
            }
        }
    }
    let mut unused = Vec::new();
    for (lint, entries) in allow.iter() {
        for e in entries.iter().filter(|e| !e.used) {
            unused.push((lint.to_string(), e.path.clone()));
        }
    }
    unused
}

// ---------------------------------------------------------------------
// Report
// ---------------------------------------------------------------------

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the machine-readable report (`torsk.pallas_audit.v1`).
pub fn render_report(
    root: &str,
    violations: &[Violation],
    unused_allow: &[(String, String)],
) -> String {
    let mut counts: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    for &l in LINTS {
        counts.insert(l, (0, 0));
    }
    for v in violations {
        let c = counts.entry(v.lint).or_insert((0, 0));
        if v.allowed.is_some() {
            c.1 += 1;
        } else {
            c.0 += 1;
        }
    }
    let blocking: usize = counts.values().map(|c| c.0).sum();

    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"torsk.pallas_audit.v1\",\n");
    s.push_str(&format!("  \"root\": \"{}\",\n", json_escape(root)));
    s.push_str(&format!("  \"clean\": {},\n", blocking == 0));
    s.push_str("  \"counts\": {\n");
    let n = counts.len();
    for (i, (lint, (bad, allowed))) in counts.iter().enumerate() {
        s.push_str(&format!(
            "    \"{}\": {{\"violations\": {}, \"allowed\": {}}}{}\n",
            lint,
            bad,
            allowed,
            if i + 1 < n { "," } else { "" }
        ));
    }
    s.push_str("  },\n");
    s.push_str("  \"violations\": [\n");
    for (i, v) in violations.iter().enumerate() {
        let allowed = match &v.allowed {
            Some(j) => format!("\"{}\"", json_escape(j)),
            None => "null".to_string(),
        };
        s.push_str(&format!(
            "    {{\"lint\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\", \"allowed\": {}}}{}\n",
            v.lint,
            json_escape(&v.file),
            v.line,
            json_escape(&v.message),
            allowed,
            if i + 1 < violations.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"unused_allowlist_entries\": [\n");
    for (i, (lint, path)) in unused_allow.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"lint\": \"{}\", \"path\": \"{}\"}}{}\n",
            json_escape(lint),
            json_escape(path),
            if i + 1 < unused_allow.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_selection() {
        let k = Scope::for_path("kernels/matmul.rs");
        assert!(k.contiguous && k.determinism && k.spawn);
        let pool = Scope::for_path("kernels/mod.rs");
        assert!(!pool.spawn, "the kernel pool is the sanctioned spawner");
        let mp = Scope::for_path("multiproc/mod.rs");
        assert!(!mp.spawn && !mp.determinism);
        let lin = Scope::for_path("dispatch/linalg.rs");
        assert!(lin.contiguous && lin.determinism && !lin.data_hash);
        let data = Scope::for_path("data/loader.rs");
        assert!(data.spawn && !data.contiguous && !data.determinism && !data.data_hash);
        let cap = Scope::for_path("dispatch/capture/mod.rs");
        assert!(cap.determinism && cap.data_hash && !cap.contiguous);
    }

    #[test]
    fn data_reads_flagged_only_in_capture_key_builders() {
        let keyed = "fn guard_key(t: &Tensor) -> String {\n    format!(\"{:?}\", t.to_vec())\n}\n";
        let v = audit_source("dispatch/capture/mod.rs", keyed).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].lint, "no-data-hash");
        assert!(v[0].message.contains("to_vec"), "{}", v[0].message);

        // Same source outside dispatch/capture/: out of scope.
        assert!(audit_source("dispatch/fuse.rs", keyed).unwrap().is_empty());

        // Metadata-only key builders stay clean, and data reads outside
        // key/guard functions are the normal, legal case.
        let clean = "fn guard_key(t: &Tensor) -> String {\n    \
                     format!(\"{:?}|{:?}|{:?}\", t.shape(), t.dtype(), t.strides())\n}\n\
                     fn run(t: &Tensor) -> Vec<f32> {\n    t.to_vec()\n}\n";
        assert!(audit_source("dispatch/capture/mod.rs", clean).unwrap().is_empty());
    }

    #[test]
    fn safety_comment_windows() {
        let ok = "fn f() {\n    // SAFETY: exclusive buffer.\n    unsafe { work() };\n}\n";
        assert!(audit_source("x.rs", ok).unwrap().is_empty());
        let inside = "fn f() {\n    unsafe {\n        // SAFETY: bounds checked above.\n        work()\n    };\n}\n";
        assert!(audit_source("x.rs", inside).unwrap().is_empty());
        let bad = "fn f() {\n    unsafe { work() };\n}\n";
        let v = audit_source("x.rs", bad).unwrap();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "safety-comment");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn unsafe_fn_doc_section_counts() {
        let src = "/// Reads raw memory.\n///\n/// # Safety\n/// Caller upholds bounds.\npub unsafe fn f() {}\n";
        assert!(audit_source("x.rs", src).unwrap().is_empty());
    }

    #[test]
    fn unsafe_inside_macro_rules_is_seen() {
        let src = "macro_rules! m {\n    () => {\n        unsafe { work() }\n    };\n}\n";
        let v = audit_source("x.rs", src).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].lint, "safety-comment");
    }

    #[test]
    fn cfg_test_modules_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() {\n        unsafe { work() };\n        let m: HashMap<u8, u8> = Default::default();\n    }\n}\n";
        assert!(audit_source("kernels/x.rs", src).unwrap().is_empty());
    }

    #[test]
    fn feature_probe_flagged_in_kernel_scope_only() {
        let probe = "fn f() -> bool {\n    std::is_x86_feature_detected!(\"avx2\")\n}\n";
        let v = audit_source("kernels/other.rs", probe).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].lint, "determinism");
        assert!(v[0].message.contains("is_x86_feature_detected"), "{}", v[0].message);
        assert!(audit_source("data/loader.rs", probe).unwrap().is_empty());

        let cpuid = "fn f() {\n    let r = unsafe { core::arch::x86_64::__cpuid(1) };\n    let _ = r;\n}\n";
        let v = audit_source("dispatch/fuse.rs", cpuid).unwrap();
        assert!(
            v.iter().any(|v| v.lint == "determinism" && v.message.contains("__cpuid")),
            "{v:?}"
        );
    }

    #[test]
    fn allowlist_round_trip() {
        let mut v = vec![Violation {
            lint: "determinism",
            file: "dispatch/mod.rs".to_string(),
            line: 3,
            message: "m".to_string(),
            allowed: None,
        }];
        let mut allow: BTreeMap<&'static str, Vec<AllowEntry>> = BTreeMap::new();
        allow.insert(
            "determinism",
            vec![
                AllowEntry {
                    path: "dispatch/mod.rs".to_string(),
                    justification: "keyed lookups only".to_string(),
                    used: false,
                },
                AllowEntry {
                    path: "dispatch/other.rs".to_string(),
                    justification: "stale".to_string(),
                    used: false,
                },
            ],
        );
        let unused = apply_allowlists(&mut v, &mut allow);
        assert_eq!(v[0].allowed.as_deref(), Some("keyed lookups only"));
        assert_eq!(unused, vec![("determinism".to_string(), "dispatch/other.rs".to_string())]);
    }

    #[test]
    fn report_is_valid_shape() {
        let v = vec![Violation {
            lint: "no-contiguous",
            file: "kernels/conv.rs".to_string(),
            line: 7,
            message: "\"quoted\"".to_string(),
            allowed: None,
        }];
        let r = render_report("rust/src", &v, &[]);
        assert!(r.contains("\"schema\": \"torsk.pallas_audit.v1\""));
        assert!(r.contains("\\\"quoted\\\""));
        assert!(r.contains("\"clean\": false"));
    }
}
