//! `pallas-audit` CLI — run the project lints over `rust/src`.
//!
//! ```text
//! cargo run -p pallas-audit [--release] -- \
//!     [--src rust/src] [--allow tools/pallas-audit/allow] \
//!     [--report audit_report.json] [--quiet]
//! ```
//!
//! Exit codes: `0` clean (allowlisted findings included), `2` unallowed
//! violations, `1` operational error (unreadable tree, parse failure,
//! malformed allowlist). Unused allowlist entries are surfaced in the
//! report and on stderr but do not fail the run.

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_audit::{apply_allowlists, audit_tree, load_allowlists, render_report};

struct Args {
    src: PathBuf,
    allow: PathBuf,
    report: PathBuf,
    quiet: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        src: PathBuf::from("rust/src"),
        allow: PathBuf::from("tools/pallas-audit/allow"),
        report: PathBuf::from("audit_report.json"),
        quiet: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut value = |name: &str| it.next().ok_or(format!("{name} needs a value"));
        match a.as_str() {
            "--src" => args.src = PathBuf::from(value("--src")?),
            "--allow" => args.allow = PathBuf::from(value("--allow")?),
            "--report" => args.report = PathBuf::from(value("--report")?),
            "--quiet" => args.quiet = true,
            other => return Err(format!("unknown argument '{other}'")),
        }
    }
    Ok(args)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("pallas-audit: {e}");
            return ExitCode::from(1);
        }
    };

    let mut violations = match audit_tree(&args.src) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("pallas-audit: {e}");
            return ExitCode::from(1);
        }
    };
    let mut allow = match load_allowlists(&args.allow) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("pallas-audit: {e}");
            return ExitCode::from(1);
        }
    };
    let unused = apply_allowlists(&mut violations, &mut allow);

    let report = render_report(&args.src.display().to_string(), &violations, &unused);
    if let Err(e) = std::fs::write(&args.report, &report) {
        eprintln!("pallas-audit: writing {}: {e}", args.report.display());
        return ExitCode::from(1);
    }

    let blocking: Vec<_> = violations.iter().filter(|v| v.allowed.is_none()).collect();
    let allowed = violations.len() - blocking.len();
    if !args.quiet {
        for v in &blocking {
            eprintln!("{}:{}: [{}] {}", v.file, v.line, v.lint, v.message);
        }
        for (lint, path) in &unused {
            eprintln!("warning: unused allowlist entry [{lint}] {path}");
        }
        eprintln!(
            "pallas-audit: {} violation(s), {} allowlisted, report at {}",
            blocking.len(),
            allowed,
            args.report.display()
        );
    }
    if blocking.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(2)
    }
}
