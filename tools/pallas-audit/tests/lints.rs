//! Negative-fixture coverage: every lint must stay *live* — able to fire
//! on a real violation — and every clean idiom must stay quiet. The
//! fixture is parsed, never compiled; see `fixtures/violations.rs`.

use std::collections::BTreeMap;

use pallas_audit::{
    apply_allowlists, audit_source, audit_tree, render_report, AllowEntry, Violation,
};

const FIXTURE: &str = include_str!("../fixtures/violations.rs");

fn count(violations: &[Violation], lint: &str) -> usize {
    violations.iter().filter(|v| v.lint == lint).count()
}

#[test]
fn every_lint_fires_on_the_fixture() {
    // Scanned as a kernel-path file: all scopes active.
    let v = audit_source("kernels/fixture.rs", FIXTURE).expect("fixture parses");
    assert_eq!(count(&v, "no-contiguous"), 1, "{v:#?}");
    assert_eq!(count(&v, "no-raw-spawn"), 2, "spawn call + Builder chain: {v:#?}");
    // `use HashMap` + the parameter type, `use Instant` + `Instant::now`.
    assert_eq!(count(&v, "determinism"), 4, "{v:#?}");
    // `unjustified_write`'s block + `undocumented_read`'s unsafe fn; the
    // justified/documented pair and the #[cfg(test)] block stay clean.
    assert_eq!(count(&v, "safety-comment"), 2, "{v:#?}");
    // One `reg.add`, one `register_op`, both sample-less; the chained
    // `.sample_inputs` pair and the bare counter `.add(1)` stay clean.
    assert_eq!(count(&v, "opinfo-samples"), 2, "{v:#?}");
    // The data-hash lint is scoped to dispatch/capture/ only.
    assert_eq!(count(&v, "no-data-hash"), 0, "{v:#?}");
}

#[test]
fn data_hash_lint_fires_under_capture_scope() {
    // Scanned as a capture-path file: the guard-key data read fires, the
    // metadata-only key builder and the non-key data read stay clean.
    let v = audit_source("dispatch/capture/fixture.rs", FIXTURE).expect("fixture parses");
    assert_eq!(count(&v, "no-data-hash"), 1, "{v:#?}");
    let hit = v.iter().find(|x| x.lint == "no-data-hash").unwrap();
    let line_text = FIXTURE.lines().nth(hit.line - 1).unwrap();
    assert!(line_text.contains("t.to_vec()"), "line {}: {line_text}", hit.line);
    // The determinism lint covers dispatch/capture/ like any dispatch path.
    assert_eq!(count(&v, "determinism"), 4, "{v:#?}");
}

#[test]
fn violations_carry_usable_locations() {
    let v = audit_source("kernels/fixture.rs", FIXTURE).expect("fixture parses");
    for violation in &v {
        assert_eq!(violation.file, "kernels/fixture.rs");
        assert!(violation.line > 0 && violation.line <= FIXTURE.lines().count());
        assert!(!violation.message.is_empty());
    }
    // Spot-check one location: the `.contiguous()` call sits on the line
    // that contains it in the fixture source.
    let contig = v.iter().find(|x| x.lint == "no-contiguous").unwrap();
    let line_text = FIXTURE.lines().nth(contig.line - 1).unwrap();
    assert!(line_text.contains(".contiguous()"), "line {}: {line_text}", contig.line);
}

#[test]
fn scoping_limits_path_lints() {
    // Outside kernel/dispatch paths: contiguous + determinism lints are
    // off, spawn + safety + opinfo stay on.
    let v = audit_source("data/fixture.rs", FIXTURE).expect("fixture parses");
    assert_eq!(count(&v, "no-contiguous"), 0);
    assert_eq!(count(&v, "determinism"), 0);
    assert_eq!(count(&v, "no-raw-spawn"), 2);
    assert_eq!(count(&v, "safety-comment"), 2);
    assert_eq!(count(&v, "opinfo-samples"), 2);

    // The multiproc layer may manage its own processes/threads.
    let v = audit_source("multiproc/fixture.rs", FIXTURE).expect("fixture parses");
    assert_eq!(count(&v, "no-raw-spawn"), 0);
}

#[test]
fn allowlist_suppresses_and_reports_rot() {
    let mut v = audit_source("kernels/fixture.rs", FIXTURE).expect("fixture parses");
    let mut allow: BTreeMap<&'static str, Vec<AllowEntry>> = BTreeMap::new();
    allow.insert(
        "no-raw-spawn",
        vec![
            AllowEntry {
                path: "kernels/fixture.rs".to_string(),
                justification: "fixture".to_string(),
                used: false,
            },
            AllowEntry {
                path: "kernels/gone.rs".to_string(),
                justification: "stale entry".to_string(),
                used: false,
            },
        ],
    );
    let unused = apply_allowlists(&mut v, &mut allow);
    assert!(v.iter().filter(|x| x.lint == "no-raw-spawn").all(|x| x.allowed.is_some()));
    assert!(v.iter().filter(|x| x.lint != "no-raw-spawn").all(|x| x.allowed.is_none()));
    assert_eq!(unused, vec![("no-raw-spawn".to_string(), "kernels/gone.rs".to_string())]);
}

#[test]
fn end_to_end_tree_walk_and_report() {
    // Build a tiny source tree in a temp dir and run the full pipeline.
    let root = std::env::temp_dir().join(format!("pallas-audit-e2e-{}", std::process::id()));
    let kernels = root.join("kernels");
    std::fs::create_dir_all(&kernels).unwrap();
    std::fs::write(kernels.join("bad.rs"), "pub fn f(t: &Tensor) -> Tensor { t.contiguous() }\n")
        .unwrap();
    std::fs::write(
        root.join("clean.rs"),
        "pub fn g(p: *mut f32) {\n    // SAFETY: exclusive pointer from the caller.\n    unsafe { *p = 0.0 };\n}\n",
    )
    .unwrap();

    let v = audit_tree(&root).expect("tree audits");
    assert_eq!(v.len(), 1, "{v:#?}");
    assert_eq!(v[0].lint, "no-contiguous");
    assert_eq!(v[0].file, "kernels/bad.rs");

    let report = render_report("tmp", &v, &[]);
    assert!(report.contains("\"schema\": \"torsk.pallas_audit.v1\""));
    assert!(report.contains("\"clean\": false"));
    assert!(report.contains("kernels/bad.rs"));

    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn unparseable_source_is_a_hard_error() {
    let err = audit_source("kernels/broken.rs", "fn f( {").unwrap_err();
    assert!(err.contains("parse error"), "{err}");
}
