"""AOT pipeline: lower every L2 train-step graph to HLO text + manifest.

Run once by ``make artifacts``:

    python -m compile.aot --out-dir ../artifacts [--only name,name]

Emits ``artifacts/<name>.hlo.txt`` (HLO **text** — xla_extension 0.5.1
rejects jax>=0.5 serialized protos with 64-bit ids; the text parser
reassigns ids) and ``artifacts/manifest.tsv`` consumed by
``rust/src/runtime``: ``name \\t num_outputs \\t spec;spec;…``.
"""

import argparse
import os
import sys
import time

import jax

# The Rust boundary uses i64 indices (PyTorch convention); without x64 JAX
# silently lowers int64 specs as int32 and the PJRT executable rejects the
# 8-byte buffers.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_string(s) -> str:
    if s.dtype == jnp.float32:
        ty = "f32"
    elif s.dtype in (jnp.int64, jnp.dtype("int64")):
        ty = "i64"
    else:
        raise ValueError(f"unsupported dtype {s.dtype}")
    return f"{ty}[{','.join(str(d) for d in s.shape)}]"


def lower_spec(spec: model.ModelSpec, out_dir: str) -> dict:
    t0 = time.time()
    lowered = jax.jit(spec.fn).lower(*spec.example_inputs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    # Output arity: run the traced fn abstractly.
    out_shapes = jax.eval_shape(spec.fn, *spec.example_inputs)
    n_out = len(out_shapes) if isinstance(out_shapes, tuple) else 1
    dt = time.time() - t0
    print(f"  {spec.name}: {len(text) / 1e6:.1f} MB HLO, {n_out} outputs, {dt:.1f}s")
    return {
        "name": spec.name,
        "n_out": n_out,
        "inputs": [spec_string(s) for s in spec.example_inputs],
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated artifact names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    only = set(x for x in args.only.split(",") if x)
    entries = []
    specs = [s for s in model.all_specs() if not only or s.name in only]
    print(f"lowering {len(specs)} artifacts -> {args.out_dir}")
    for spec in specs:
        entries.append(lower_spec(spec, args.out_dir))

    manifest_path = os.path.join(args.out_dir, "manifest.tsv")
    existing = {}
    if os.path.exists(manifest_path) and only:
        # Partial regeneration keeps other entries.
        with open(manifest_path) as f:
            for line in f:
                if line.strip() and not line.startswith("#"):
                    existing[line.split("\t")[0]] = line.rstrip("\n")
    for e in entries:
        existing[e["name"]] = f"{e['name']}\t{e['n_out']}\t{';'.join(e['inputs'])}"
    with open(manifest_path, "w") as f:
        f.write("# torsk AOT manifest: name \\t num_outputs \\t input specs\n")
        for name in sorted(existing):
            f.write(existing[name] + "\n")
    print(f"wrote {manifest_path} ({len(existing)} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
