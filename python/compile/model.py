"""L2: JAX train-step graphs for the torsk benchmark models.

Each model here mirrors its Rust eager twin (rust/src/models/*) and is
lowered once by ``aot.py`` into a whole-train-step XLA graph

    step(batch..., *params) -> (loss, *updated_params)

with the SGD update fused into the graph — the static-graph execution
mode that stands in for TensorFlow/CNTK/MXNet in Table 1 (DESIGN.md §2).
Compute hot-spots go through the L1 Pallas kernels (matmul/linear,
softmax-xent, LSTM gates); convolutions use lax.conv (the XLA "vendor
kernel") in the big CNN graphs, with the Pallas im2col+matmul conv
exercised by the standalone `conv_block` artifact and the kernel tests.

Parameter order is the flattened list order of each model's `init()`;
the Rust side reads shapes from the manifest, so only the *order* is a
contract (documented per model below).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import conv2d as pk_conv
from .kernels import lstm_cell as pk_lstm
from .kernels import matmul as pk_matmul
from .kernels import ref
from .kernels import softmax_xent as pk_sx


# ----------------------------------------------------------------------
# Common pieces
# ----------------------------------------------------------------------

def _sgd(params, grads, lr):
    return [p - lr * g for p, g in zip(params, grads)]


def _kaiming(key, shape):
    fan_in = 1
    for d in shape[1:]:
        fan_in *= d
    bound = (2.0 ** 0.5) * (3.0 / fan_in) ** 0.5
    return jax.random.uniform(key, shape, jnp.float32, -bound, bound)


def _keys(key, n):
    return list(jax.random.split(key, n))


class ModelSpec:
    """What aot.py needs to lower one artifact."""

    def __init__(self, name, fn, example_inputs, n_batch_inputs):
        self.name = name
        self.fn = fn
        self.example_inputs = example_inputs
        self.n_batch_inputs = n_batch_inputs


# ----------------------------------------------------------------------
# MLP (quickstart + eager-vs-graph agreement tests)
# Params: [w1 [H,I], b1 [H], w2 [C,H], b2 [C]]
# ----------------------------------------------------------------------

MLP_IN, MLP_HIDDEN, MLP_CLASSES, MLP_BATCH = 16, 32, 4, 8


def mlp_forward(x, params):
    w1, b1, w2, b2 = params
    h = jax.nn.relu(pk_matmul.linear(x, w1, b1))
    return pk_matmul.linear(h, w2, b2)


def mlp_loss(params, x, y):
    return pk_sx.softmax_xent(mlp_forward(x, params), y)


def mlp_step(lr, x, y, *params):
    params = list(params)
    loss, grads = jax.value_and_grad(mlp_loss)(params, x, y)
    return tuple([loss] + _sgd(params, grads, lr))


def mlp_init(seed=0):
    ks = _keys(jax.random.PRNGKey(seed), 4)
    return [
        _kaiming(ks[0], (MLP_HIDDEN, MLP_IN)),
        jnp.zeros((MLP_HIDDEN,), jnp.float32),
        _kaiming(ks[1], (MLP_CLASSES, MLP_HIDDEN)),
        jnp.zeros((MLP_CLASSES,), jnp.float32),
    ]


def mlp_spec():
    x = jax.ShapeDtypeStruct((MLP_BATCH, MLP_IN), jnp.float32)
    y = jax.ShapeDtypeStruct((MLP_BATCH,), jnp.int64)
    params = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in mlp_init()]
    return ModelSpec("mlp_step", functools.partial(mlp_step, 0.1), [x, y] + params, 2)


# ----------------------------------------------------------------------
# Generic CNN builder mirroring the Rust model configs.
# A layer spec is one of:
#   ("conv", c_in, c_out, k, stride, pad, groups)  [+ bias]
#   ("relu",) ("maxpool", k, s) ("gap",) ("flatten",)
#   ("linear", d_in, d_out)
# Params: for each conv: w, b ; for each linear: w, b — in layer order.
# ----------------------------------------------------------------------

def cnn_forward(x, params, layers):
    i = 0
    for spec in layers:
        kind = spec[0]
        if kind == "conv":
            _, c_in, c_out, k, stride, pad, groups = spec
            w, b = params[i], params[i + 1]
            i += 2
            x = ref.conv2d_ref(x, w, b, stride=stride, padding=pad, groups=groups)
        elif kind == "relu":
            x = jax.nn.relu(x)
        elif kind == "maxpool":
            _, k, s = spec
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, s, s), "VALID"
            )
        elif kind == "gap":
            x = jnp.mean(x, axis=(2, 3))
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "linear":
            w, b = params[i], params[i + 1]
            i += 2
            x = pk_matmul.linear(x, w, b)
        else:
            raise ValueError(kind)
    assert i == len(params), f"consumed {i} of {len(params)} params"
    return x


def cnn_init(layers, seed=0):
    key = jax.random.PRNGKey(seed)
    params = []
    for spec in layers:
        if spec[0] == "conv":
            _, c_in, c_out, k, stride, pad, groups = spec
            key, k1 = jax.random.split(key)
            params.append(_kaiming(k1, (c_out, c_in // groups, k, k)))
            params.append(jnp.zeros((c_out,), jnp.float32))
        elif spec[0] == "linear":
            _, d_in, d_out = spec
            key, k1 = jax.random.split(key)
            params.append(_kaiming(k1, (d_out, d_in)))
            params.append(jnp.zeros((d_out,), jnp.float32))
    return params


def cnn_step(layers, lr, x, y, *params):
    params = list(params)

    def loss_fn(ps):
        return pk_sx.softmax_xent(cnn_forward(x, ps, layers), y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return tuple([loss] + _sgd(params, grads, lr))


def _cnn_spec(name, layers, batch, hw=32, classes=10, lr=0.05):
    x = jax.ShapeDtypeStruct((batch, 3, hw, hw), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int64)
    params = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in cnn_init(layers)]
    return ModelSpec(name, functools.partial(cnn_step, layers, lr), [x, y] + params, 2)


def alexnet_layers():
    """Mirror of rust/src/models/alexnet.rs (width/4, 32x32)."""
    return [
        ("conv", 3, 16, 3, 1, 1, 1), ("relu",), ("maxpool", 2, 2),
        ("conv", 16, 48, 3, 1, 1, 1), ("relu",), ("maxpool", 2, 2),
        ("conv", 48, 96, 3, 1, 1, 1), ("relu",),
        ("conv", 96, 64, 3, 1, 1, 1), ("relu",),
        ("conv", 64, 64, 3, 1, 1, 1), ("relu",), ("maxpool", 2, 2),
        ("flatten",),
        ("linear", 64 * 4 * 4, 512), ("relu",),
        ("linear", 512, 256), ("relu",),
        ("linear", 256, 10),
    ]


def vgg19_layers():
    layers = []
    c = 3
    for width, convs in [(16, 2), (32, 2), (64, 4), (128, 4), (128, 4)]:
        for _ in range(convs):
            layers += [("conv", c, width, 3, 1, 1, 1), ("relu",)]
            c = width
        layers.append(("maxpool", 2, 2))
    layers += [
        ("flatten",),
        ("linear", 128, 256), ("relu",),
        ("linear", 256, 256), ("relu",),
        ("linear", 256, 10),
    ]
    return layers


def mobilenet_layers():
    """Depthwise-separable stack (width/2), no BN in the graph twin."""
    layers = [("conv", 3, 16, 3, 1, 1, 1), ("relu",)]

    def sep(c_in, c_out, stride):
        return [
            ("conv", c_in, c_in, 3, stride, 1, c_in), ("relu",),
            ("conv", c_in, c_out, 1, 1, 0, 1), ("relu",),
        ]

    layers += sep(16, 32, 1)
    layers += sep(32, 64, 2)
    layers += sep(64, 64, 1)
    layers += sep(64, 128, 2)
    layers += sep(128, 128, 1)
    layers += sep(128, 256, 2)
    for _ in range(5):
        layers += sep(256, 256, 1)
    layers += sep(256, 512, 2)
    layers += sep(512, 512, 1)
    layers += [("gap",), ("linear", 512, 10)]
    return layers


# ResNet-50 graph twin: bottleneck blocks, BN replaced by bias (graph
# baselines in Table 1 share kernels, not training semantics; the eager
# twin's BN is exercised in Rust).
def resnet50_layers_blocks():
    widths = [16, 32, 64, 128]
    blocks = [3, 4, 6, 3]
    return widths, blocks


def resnet50_init(seed=0):
    widths, blocks = resnet50_layers_blocks()
    key = jax.random.PRNGKey(seed)
    params = []

    def conv_param(c_in, c_out, k):
        nonlocal key
        key, k1 = jax.random.split(key)
        params.append(_kaiming(k1, (c_out, c_in, k, k)))
        params.append(jnp.zeros((c_out,), jnp.float32))

    conv_param(3, 16, 3)  # stem
    c = 16
    for s, (w, n) in enumerate(zip(widths, blocks)):
        for b in range(n):
            c_out = w * 4
            conv_param(c, w, 1)
            conv_param(w, w, 3)
            conv_param(w, c_out, 1)
            if b == 0:  # downsample projection
                conv_param(c, c_out, 1)
            c = c_out
    # fc
    key, k1 = jax.random.split(key)
    params.append(_kaiming(k1, (10, 512)))
    params.append(jnp.zeros((10,), jnp.float32))
    return params


def resnet50_forward(x, params):
    widths, blocks = resnet50_layers_blocks()
    i = 0

    def conv(x, stride=1, pad=0):
        nonlocal i
        w, b = params[i], params[i + 1]
        i += 2
        return ref.conv2d_ref(x, w, b, stride=stride, padding=pad)

    x = jax.nn.relu(conv(x, stride=1, pad=1))  # stem
    for s, (w_, n) in enumerate(zip(widths, blocks)):
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            out = jax.nn.relu(conv(x, stride=1, pad=0))
            out = jax.nn.relu(conv(out, stride=stride, pad=1))
            out = conv(out, stride=1, pad=0)
            if b == 0:
                identity = conv(x, stride=stride, pad=0)
            else:
                identity = x
            x = jax.nn.relu(out + identity)
    x = jnp.mean(x, axis=(2, 3))
    w, b = params[i], params[i + 1]
    i += 2
    assert i == len(params)
    return pk_matmul.linear(x, w, b)


def resnet50_step(lr, x, y, *params):
    params = list(params)

    def loss_fn(ps):
        return pk_sx.softmax_xent(resnet50_forward(x, ps), y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return tuple([loss] + _sgd(params, grads, lr))


def resnet50_spec(batch=16):
    x = jax.ShapeDtypeStruct((batch, 3, 32, 32), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int64)
    params = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in resnet50_init()]
    return ModelSpec("resnet50_step", functools.partial(resnet50_step, 0.05), [x, y] + params, 2)


# ----------------------------------------------------------------------
# GNMT: LSTM encoder/decoder + dot attention (scaled like the Rust twin).
# Params: [embed, enc(w_ih,w_hh,b)x2, dec(w_ih,w_hh,b)x2, attn_w, attn_b,
#          proj_w, proj_b]
# ----------------------------------------------------------------------

GNMT_VOCAB, GNMT_DIM, GNMT_LAYERS = 4096, 128, 2
GNMT_BATCH, GNMT_SRC, GNMT_TGT = 32, 16, 16


def gnmt_init(seed=0):
    key = jax.random.PRNGKey(seed)
    params = []
    key, k1 = jax.random.split(key)
    params.append(jax.random.normal(k1, (GNMT_VOCAB, GNMT_DIM), jnp.float32))
    for _ in range(2 * GNMT_LAYERS):  # enc layers then dec layers
        key, k1, k2 = jax.random.split(key, 3)
        params.append(_kaiming(k1, (4 * GNMT_DIM, GNMT_DIM)))
        params.append(_kaiming(k2, (4 * GNMT_DIM, GNMT_DIM)))
        params.append(jnp.zeros((4 * GNMT_DIM,), jnp.float32))
    key, k1, k2 = jax.random.split(key, 3)
    params.append(_kaiming(k1, (GNMT_DIM, 2 * GNMT_DIM)))  # attn_out
    params.append(jnp.zeros((GNMT_DIM,), jnp.float32))
    params.append(_kaiming(k2, (GNMT_VOCAB, GNMT_DIM)))  # proj
    params.append(jnp.zeros((GNMT_VOCAB,), jnp.float32))
    return params


def _run_lstm(xs, cells):
    """xs [T, N, D]; cells = [(w_ih, w_hh, b), ...]. Returns (ys, finals)."""
    n = xs.shape[1]
    h0 = [(jnp.zeros((n, GNMT_DIM), jnp.float32), jnp.zeros((n, GNMT_DIM), jnp.float32)) for _ in cells]

    def step(state, x):
        new_state = []
        inp = x
        for (h, c), (w_ih, w_hh, b) in zip(state, cells):
            h2, c2 = pk_lstm.lstm_cell(inp, h, c, w_ih, w_hh, b)
            new_state.append((h2, c2))
            inp = h2
        return new_state, inp

    finals, ys = jax.lax.scan(step, h0, xs)
    return ys, finals


def gnmt_forward_loss(params, src, tgt):
    embed = params[0]
    idx = 1
    enc_cells = []
    for _ in range(GNMT_LAYERS):
        enc_cells.append((params[idx], params[idx + 1], params[idx + 2]))
        idx += 3
    dec_cells = []
    for _ in range(GNMT_LAYERS):
        dec_cells.append((params[idx], params[idx + 1], params[idx + 2]))
        idx += 3
    attn_w, attn_b = params[idx], params[idx + 1]
    proj_w, proj_b = params[idx + 2], params[idx + 3]

    n, t_len = tgt.shape
    src_emb = embed[src].transpose(1, 0, 2)  # [S, N, D]
    enc_states, _ = _run_lstm(src_emb, enc_cells)  # [S, N, D]
    memory = enc_states.transpose(1, 0, 2)  # [N, S, D]

    bos = jnp.zeros((n, 1), tgt.dtype)
    tgt_in = jnp.concatenate([bos, tgt[:, : t_len - 1]], axis=1)
    tgt_emb = embed[tgt_in].transpose(1, 0, 2)  # [T, N, D]
    dec_states, _ = _run_lstm(tgt_emb, dec_cells)  # [T, N, D]
    dec_btd = dec_states.transpose(1, 0, 2)  # [N, T, D]

    scores = jnp.einsum("ntd,nsd->nts", dec_btd, memory) / (GNMT_DIM ** 0.5)
    weights = jax.nn.softmax(scores, axis=-1)
    context = jnp.einsum("nts,nsd->ntd", weights, memory)
    combined = jnp.concatenate([context, dec_btd], axis=-1)  # [N, T, 2D]
    attn = jnp.tanh(
        pk_matmul.linear(combined.reshape(-1, 2 * GNMT_DIM), attn_w, attn_b)
    )
    logits = pk_matmul.linear(attn, proj_w, proj_b)  # [N*T, V]
    return pk_sx.softmax_xent(logits, tgt.reshape(-1))


def gnmt_step(lr, src, tgt, *params):
    params = list(params)
    loss, grads = jax.value_and_grad(gnmt_forward_loss)(params, src, tgt)
    return tuple([loss] + _sgd(params, grads, lr))


def gnmt_spec():
    src = jax.ShapeDtypeStruct((GNMT_BATCH, GNMT_SRC), jnp.int64)
    tgt = jax.ShapeDtypeStruct((GNMT_BATCH, GNMT_TGT), jnp.int64)
    params = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in gnmt_init()]
    return ModelSpec("gnmt_step", functools.partial(gnmt_step, 0.05), [src, tgt] + params, 2)


# ----------------------------------------------------------------------
# NCF: GMF + MLP towers, BCE loss.
# Params: [u_gmf, i_gmf, u_mlp, i_mlp, w1,b1, w2,b2, w3,b3, head_w, head_b]
# ----------------------------------------------------------------------

NCF_USERS, NCF_ITEMS, NCF_DIM, NCF_BATCH = 16384, 16384, 32, 1024


def ncf_forward(params, users, items):
    u_gmf, i_gmf, u_mlp, i_mlp, w1, b1, w2, b2, w3, b3, hw, hb = params
    gmf = u_gmf[users] * i_gmf[items]
    h = jnp.concatenate([u_mlp[users], i_mlp[items]], axis=1)
    h = jax.nn.relu(pk_matmul.linear(h, w1, b1))
    h = jax.nn.relu(pk_matmul.linear(h, w2, b2))
    h = jax.nn.relu(pk_matmul.linear(h, w3, b3))
    fused = jnp.concatenate([gmf, h], axis=1)
    return jax.nn.sigmoid(pk_matmul.linear(fused, hw, hb))[:, 0]


def ncf_loss(params, users, items, labels):
    p = jnp.clip(ncf_forward(params, users, items), 1e-7, 1 - 1e-7)
    return -jnp.mean(labels * jnp.log(p) + (1 - labels) * jnp.log(1 - p))


def ncf_init(seed=0):
    ks = _keys(jax.random.PRNGKey(seed), 12)
    d = NCF_DIM
    return [
        jax.random.normal(ks[0], (NCF_USERS, d), jnp.float32),
        jax.random.normal(ks[1], (NCF_ITEMS, d), jnp.float32),
        jax.random.normal(ks[2], (NCF_USERS, d), jnp.float32),
        jax.random.normal(ks[3], (NCF_ITEMS, d), jnp.float32),
        _kaiming(ks[4], (2 * d, 2 * d)), jnp.zeros((2 * d,), jnp.float32),
        _kaiming(ks[5], (d, 2 * d)), jnp.zeros((d,), jnp.float32),
        _kaiming(ks[6], (d // 2, d)), jnp.zeros((d // 2,), jnp.float32),
        _kaiming(ks[7], (1, d + d // 2)), jnp.zeros((1,), jnp.float32),
    ]


def ncf_step(lr, users, items, labels, *params):
    params = list(params)
    loss, grads = jax.value_and_grad(ncf_loss)(params, users, items, labels)
    return tuple([loss] + _sgd(params, grads, lr))


def ncf_spec():
    users = jax.ShapeDtypeStruct((NCF_BATCH,), jnp.int64)
    items = jax.ShapeDtypeStruct((NCF_BATCH,), jnp.int64)
    labels = jax.ShapeDtypeStruct((NCF_BATCH,), jnp.float32)
    params = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in ncf_init()]
    return ModelSpec("ncf_step", functools.partial(ncf_step, 0.05), [users, items, labels] + params, 3)


# ----------------------------------------------------------------------
# Standalone fused-kernel artifact: a conv block through the Pallas
# im2col+matmul conv (proves the L1 conv path lowers and runs via PJRT).
# ----------------------------------------------------------------------

def conv_block(x, w, b):
    return jax.nn.relu(pk_conv.conv2d(x, w, b, stride=1, padding=1))


def conv_block_spec():
    x = jax.ShapeDtypeStruct((4, 8, 16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 8, 3, 3), jnp.float32)
    b = jax.ShapeDtypeStruct((16,), jnp.float32)
    return ModelSpec("conv_block", lambda x, w, b: (conv_block(x, w, b),), [x, w, b], 3)


def all_specs():
    """Every artifact aot.py should produce."""
    return [
        mlp_spec(),
        _cnn_spec("alexnet_step", alexnet_layers(), batch=32),
        _cnn_spec("vgg19_step", vgg19_layers(), batch=16),
        resnet50_spec(batch=16),
        _cnn_spec("mobilenet_step", mobilenet_layers(), batch=32),
        gnmt_spec(),
        ncf_spec(),
        conv_block_spec(),
    ]
