"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every L1 kernel in this package has a reference implementation here, and
``python/tests/test_kernels.py`` sweeps shapes/dtypes (hypothesis) asserting
``assert_allclose(kernel(...), ref(...))``. These oracles are also what the
L2 models are differentiated against conceptually — the kernels must be
drop-in replacements.
"""

import jax
import jax.numpy as jnp


def matmul_ref(a, b):
    """C = A @ B for 2-D f32 operands."""
    return jnp.matmul(a, b)


def linear_ref(x, w, b):
    """PyTorch Linear layout: y = x @ w.T + b with w [out, in]."""
    return jnp.matmul(x, w.T) + b


def conv2d_ref(x, w, b=None, stride=1, padding=0, groups=1):
    """NCHW conv via lax.conv_general_dilated (the cuDNN-equivalent)."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out


def softmax_ref(x):
    """Row softmax over the last dim, numerically stable."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def log_softmax_ref(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    return x - m - jnp.log(jnp.sum(jnp.exp(x - m), axis=-1, keepdims=True))


def softmax_xent_ref(logits, targets):
    """Mean cross-entropy of i32/i64 targets against [N, C] logits."""
    lp = log_softmax_ref(logits)
    n = logits.shape[0]
    picked = lp[jnp.arange(n), targets]
    return -jnp.mean(picked)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last dim."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def lstm_gates_ref(preact, c):
    """Fused LSTM gate math: preact [N, 4H] (i,f,g,o blocks), cell c [N, H].

    Returns (h', c').
    """
    hsz = c.shape[-1]
    i = jax.nn.sigmoid(preact[:, 0 * hsz:1 * hsz])
    f = jax.nn.sigmoid(preact[:, 1 * hsz:2 * hsz])
    g = jnp.tanh(preact[:, 2 * hsz:3 * hsz])
    o = jax.nn.sigmoid(preact[:, 3 * hsz:4 * hsz])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new
