"""L1 Pallas kernel: fused row-softmax cross-entropy.

Row-tiled VPU kernel: each grid step loads one block of logit rows into
VMEM, computes the stable log-sum-exp, picks the target log-prob, and
emits per-row losses (mean-reduced by the wrapper). Fusing the pick into
the softmax avoids materializing [N, C] log-probs in HBM — the same
motivation as cuDNN's fused softmax losses.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 64


def _xent_kernel(logits_ref, target_ref, loss_ref):
    x = logits_ref[...]  # [R, C]
    t = target_ref[...]  # [R]
    m = jnp.max(x, axis=-1, keepdims=True)
    shifted = x - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[:, 0]
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    picked = jnp.sum(jnp.where(cols == t[:, None], x, 0.0), axis=-1)
    loss_ref[...] = lse - picked


def _xent_forward(logits, targets):
    """Mean cross-entropy: logits [N, C] f32, targets [N] int32/int64."""
    n, c = logits.shape
    targets = targets.astype(jnp.int32)
    pad = (-n) % BLOCK_ROWS
    logits_p = jnp.pad(logits, ((0, pad), (0, 0)))
    # Padded rows get target 0; their loss is masked out below.
    targets_p = jnp.pad(targets, (0, pad))
    rows = logits_p.shape[0]

    losses = pl.pallas_call(
        _xent_kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, c), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(logits_p, targets_p)
    return jnp.sum(losses[:n]) / n


@jax.custom_vjp
def softmax_xent(logits, targets):
    """Mean cross-entropy: logits [N, C] f32, targets [N] int."""
    return _xent_forward(logits, targets)


def _xent_fwd(logits, targets):
    return _xent_forward(logits, targets), (logits, targets)


def _xent_bwd(res, g):
    logits, targets = res
    n, c = logits.shape
    sm = softmax(logits)
    onehot = jax.nn.one_hot(targets, c, dtype=logits.dtype)
    return ((sm - onehot) * (g / n), None)


softmax_xent.defvjp(_xent_fwd, _xent_bwd)


@functools.partial(jax.jit, static_argnames=())
def softmax(x):
    """Row softmax via a Pallas kernel (last-dim)."""
    orig_shape = x.shape
    c = x.shape[-1]
    x2 = x.reshape(-1, c)
    n = x2.shape[0]
    pad = (-n) % BLOCK_ROWS
    xp = jnp.pad(x2, ((0, pad), (0, 0)))

    def _softmax_kernel(x_ref, o_ref):
        v = x_ref[...]
        m = jnp.max(v, axis=-1, keepdims=True)
        e = jnp.exp(v - m)
        o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)

    out = pl.pallas_call(
        _softmax_kernel,
        grid=(xp.shape[0] // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp)
    return out[:n].reshape(orig_shape)
