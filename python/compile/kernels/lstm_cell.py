"""L1 Pallas kernel: fused LSTM gate math.

The gate projections (two matmuls) go through the MXU via the Pallas
matmul; this kernel fuses the remaining VPU work — 3 sigmoids, 2 tanhs,
2 multiplies, 1 add — into one VMEM pass over the [N, 4H] preactivations,
instead of 8 separate elementwise HLO ops bouncing through HBM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as pk_matmul

BLOCK_ROWS = 32


def _gates_kernel(pre_ref, c_ref, h_out_ref, c_out_ref):
    pre = pre_ref[...]  # [R, 4H]
    c = c_ref[...]  # [R, H]
    hsz = c.shape[-1]
    i = jax.nn.sigmoid(pre[:, 0 * hsz:1 * hsz])
    f = jax.nn.sigmoid(pre[:, 1 * hsz:2 * hsz])
    g = jnp.tanh(pre[:, 2 * hsz:3 * hsz])
    o = jax.nn.sigmoid(pre[:, 3 * hsz:4 * hsz])
    c_new = f * c + i * g
    h_out_ref[...] = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new


def _lstm_gates_forward(preact, c):
    """Fused gate math: preact [N, 4H], c [N, H] -> (h', c')."""
    n, hsz4 = preact.shape
    hsz = hsz4 // 4
    pad = (-n) % BLOCK_ROWS
    pre_p = jnp.pad(preact, ((0, pad), (0, 0)))
    c_p = jnp.pad(c, ((0, pad), (0, 0)))
    rows = pre_p.shape[0]

    h_new, c_new = pl.pallas_call(
        _gates_kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, 4 * hsz), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, hsz), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, hsz), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, hsz), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, hsz), jnp.float32),
            jax.ShapeDtypeStruct((rows, hsz), jnp.float32),
        ],
        interpret=True,
    )(pre_p, c_p)
    return h_new[:n], c_new[:n]


@jax.custom_vjp
def lstm_gates(preact, c):
    """Differentiable fused LSTM gate math (VJP via gate formulas)."""
    return _lstm_gates_forward(preact, c)


def _gates_fwd(preact, c):
    out = _lstm_gates_forward(preact, c)
    return out, (preact, c)


def _gates_bwd(res, grads):
    preact, c = res
    gh, gc_out = grads
    hsz = c.shape[-1]
    i = jax.nn.sigmoid(preact[:, 0 * hsz:1 * hsz])
    f = jax.nn.sigmoid(preact[:, 1 * hsz:2 * hsz])
    g = jnp.tanh(preact[:, 2 * hsz:3 * hsz])
    o = jax.nn.sigmoid(preact[:, 3 * hsz:4 * hsz])
    c_new = f * c + i * g
    tc = jnp.tanh(c_new)
    # dL/dc_new from both outputs.
    dc_new = gc_out + gh * o * (1.0 - tc * tc)
    do = gh * tc
    di = dc_new * g
    df = dc_new * c
    dg = dc_new * i
    dpre = jnp.concatenate([
        di * i * (1 - i),
        df * f * (1 - f),
        dg * (1 - g * g),
        do * o * (1 - o),
    ], axis=-1)
    dc = dc_new * f
    return dpre, dc


lstm_gates.defvjp(_gates_fwd, _gates_bwd)


def lstm_cell(x, h, c, w_ih, w_hh, b):
    """Full LSTM step: MXU projections + fused gates.

    x [N, I], h/c [N, H], w_ih [4H, I], w_hh [4H, H], b [4H].
    """
    preact = pk_matmul.linear(x, w_ih, b) + pk_matmul.matmul(h, w_hh.T)
    return lstm_gates(preact, c)
