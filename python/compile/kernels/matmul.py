"""L1 Pallas kernel: MXU-tiled blocked matmul.

The compute hot-spot of every Table 1 model (fc layers, im2col'd convs,
LSTM gate projections, attention). Tiled for the TPU memory hierarchy:

- block shapes default to 128x128x128 — MXU-aligned (the systolic array is
  128x128) and VMEM-frugal: 3 f32 blocks live = 192 KiB out of ~16 MiB, so
  the scheduler has ample room to double-buffer HBM->VMEM copies;
- the K loop is the innermost grid dimension, accumulating into the output
  block resident in VMEM (revisited across the K grid steps);
- operands are zero-padded to block multiples by the wrapper, keeping the
  kernel branch-free (dimension-order guarantees in Mosaic).

Runs under ``interpret=True`` everywhere in this repo: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see DESIGN.md §Hardware-Adaptation for
estimated real-TPU characteristics).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (m, n, k) grid step: o[m,n] += a[m,k] @ b[k,n]."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_raw(a, b, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """C = A @ B via the Pallas kernel, any (m, k) x (k, n) f32 shapes.

    Forward-only primitive; use [`matmul`] for the differentiable op.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    a_p = _pad_to(_pad_to(a, bm, 0), bk, 1)
    b_p = _pad_to(_pad_to(b, bk, 0), bn, 1)
    mp, kp = a_p.shape
    _, np_ = b_p.shape

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def linear(x, w, b=None):
    """PyTorch-layout linear: x [N, in] @ w[out, in].T + b."""
    y = matmul(x, w.T)
    if b is not None:
        y = y + b
    return y


# Differentiable wrapper: Pallas kernels are forward primitives; the VJP is
# expressed with the same kernel (dA = G @ Bᵀ, dB = Aᵀ @ G), exactly how
# production frameworks register hand-written backward kernels.
@jax.custom_vjp
def matmul(a, b):
    return matmul_raw(a, b)


def _matmul_fwd(a, b):
    return matmul_raw(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return matmul_raw(g, b.T), matmul_raw(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
