"""L1 kernel composition: conv2d = im2col (data movement) + Pallas matmul
(MXU compute).

Hardware adaptation (DESIGN.md §2): GPU convs tile threadblocks over
output pixels with shared-memory staging; on TPU the winning strategy is
to reshape convolution into the MXU's native matmul. im2col materializes
the patch matrix (pure layout work XLA fuses into the surrounding
computation), and the 128x128-tiled Pallas matmul does the FLOPs.
"""

import functools

import jax
import jax.numpy as jnp

from . import matmul as pk_matmul


def _im2col(x, kh, kw, stride, padding):
    """x [N,C,H,W] -> patches [N, C*KH*KW, HO*WO]."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    # Gather kh*kw strided slices; unrolled at trace time (kh,kw static).
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            sl = jax.lax.slice(
                xp,
                (0, 0, ky, kx),
                (n, c, ky + (ho - 1) * stride + 1, kx + (wo - 1) * stride + 1),
                (1, 1, stride, stride),
            )  # [N, C, HO, WO]
            cols.append(sl.reshape(n, c, 1, ho * wo))
    col = jnp.concatenate(cols, axis=2)  # [N, C, KH*KW, HO*WO]
    return col.reshape(n, c * kh * kw, ho * wo), ho, wo


@functools.partial(jax.jit, static_argnames=("stride", "padding", "groups"))
def conv2d(x, w, b=None, stride=1, padding=0, groups=1):
    """NCHW conv, weights OIHW [C_out, C_in/groups, KH, KW]."""
    n, c_in, _, _ = x.shape
    c_out, cg_in, kh, kw = w.shape
    assert c_in % groups == 0 and c_out % groups == 0
    assert cg_in == c_in // groups

    outs = []
    cg_out = c_out // groups
    for g in range(groups):
        xg = x[:, g * cg_in:(g + 1) * cg_in]
        wg = w[g * cg_out:(g + 1) * cg_out].reshape(cg_out, cg_in * kh * kw)
        col, ho, wo = _im2col(xg, kh, kw, stride, padding)  # [N, R, P]
        # Batch the N dimension into the matmul M dimension:
        # [N, R, P] -> [R, N*P] so one big MXU matmul covers the batch.
        r = col.shape[1]
        col2 = col.transpose(1, 0, 2).reshape(r, -1)
        yg = pk_matmul.matmul(wg, col2)  # [cg_out, N*P]
        yg = yg.reshape(cg_out, n, ho * wo).transpose(1, 0, 2)
        outs.append(yg.reshape(n, cg_out, ho, wo))
    out = outs[0] if groups == 1 else jnp.concatenate(outs, axis=1)
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out
