"""L1 Pallas kernel: row LayerNorm (VPU, row-tiled).

One VMEM-resident block of rows per grid step; mean/variance/normalize
fused in a single pass over the block (two reads of x, one write), versus
the 4+ HBM round-trips of the unfused composition.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 64


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = xc * inv * g_ref[...] + b_ref[...]


def _layernorm_forward(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last dim; x [..., D], gamma/beta [D]."""
    orig = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    pad = (-n) % BLOCK_ROWS
    xp = jnp.pad(x2, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(xp.shape[0] // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, gamma, beta)
    return out[:n].reshape(orig)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def layernorm(x, gamma, beta, eps=1e-5):
    """Differentiable fused LayerNorm (VJP via the standard formulas)."""
    return _layernorm_forward(x, gamma, beta, eps)


def _ln_fwd(x, gamma, beta, eps):
    return _layernorm_forward(x, gamma, beta, eps), (x, gamma)


def _ln_bwd(eps, res, g):
    x, gamma = res
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = xc * inv
    d = x.shape[-1]
    gx = g * gamma
    dx = inv * (gx - jnp.mean(gx, axis=-1, keepdims=True)
                - xhat * jnp.mean(gx * xhat, axis=-1, keepdims=True))
    # Reduce over all leading dims for the affine params.
    reduce_axes = tuple(range(x.ndim - 1))
    dgamma = jnp.sum(g * xhat, axis=reduce_axes)
    dbeta = jnp.sum(g, axis=reduce_axes)
    return dx, dgamma, dbeta


layernorm.defvjp(_ln_fwd, _ln_bwd)

