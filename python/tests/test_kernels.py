"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes; every kernel must be a drop-in replacement for
its reference — this is the CORE correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as pk_conv
from compile.kernels import layernorm as pk_ln
from compile.kernels import lstm_cell as pk_lstm
from compile.kernels import matmul as pk_matmul
from compile.kernels import ref
from compile.kernels import softmax_xent as pk_sx

DIMS = st.integers(min_value=1, max_value=40)


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ----------------------------------------------------------------- matmul

@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, seed):
    a = rand(seed, (m, k))
    b = rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        pk_matmul.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
    )


def test_matmul_exact_block_multiple():
    a = rand(0, (128, 256))
    b = rand(1, (256, 128))
    np.testing.assert_allclose(pk_matmul.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_larger_than_one_block():
    a = rand(2, (200, 300))
    b = rand(3, (300, 150))
    np.testing.assert_allclose(pk_matmul.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_linear_matches_ref():
    x, w, b = rand(4, (7, 13)), rand(5, (5, 13)), rand(6, (5,))
    np.testing.assert_allclose(
        pk_matmul.linear(x, w, b), ref.linear_ref(x, w, b), rtol=1e-4, atol=1e-4
    )


def test_matmul_is_differentiable():
    a, b = rand(7, (6, 5)), rand(8, (5, 4))
    g1 = jax.grad(lambda a: jnp.sum(pk_matmul.matmul(a, b)))(a)
    g2 = jax.grad(lambda a: jnp.sum(ref.matmul_ref(a, b)))(a)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ softmax/xent

@settings(max_examples=20, deadline=None)
@given(n=DIMS, c=st.integers(2, 30), seed=st.integers(0, 2**16))
def test_softmax_xent_matches_ref(n, c, seed):
    logits = rand(seed, (n, c), -5, 5)
    targets = jax.random.randint(jax.random.PRNGKey(seed + 9), (n,), 0, c)
    np.testing.assert_allclose(
        pk_sx.softmax_xent(logits, targets),
        ref.softmax_xent_ref(logits, targets),
        rtol=1e-5,
        atol=1e-5,
    )


def test_softmax_matches_ref():
    x = rand(11, (33, 17), -8, 8)
    np.testing.assert_allclose(pk_sx.softmax(x), ref.softmax_ref(x), rtol=1e-5, atol=1e-6)


def test_softmax_stable_for_huge_logits():
    x = jnp.array([[1000.0, 1001.0, 999.0]])
    out = np.asarray(pk_sx.softmax(x))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-5)


def test_xent_gradient_matches_ref():
    logits = rand(12, (9, 6), -3, 3)
    targets = jax.random.randint(jax.random.PRNGKey(13), (9,), 0, 6)
    g1 = jax.grad(lambda l: pk_sx.softmax_xent(l, targets))(logits)
    g2 = jax.grad(lambda l: ref.softmax_xent_ref(l, targets))(logits)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- conv2d

@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 3),
    c_in=st.integers(1, 4),
    c_out=st.integers(1, 4),
    hw=st.integers(4, 12),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(n, c_in, c_out, hw, k, stride, seed):
    pad = k // 2
    x = rand(seed, (n, c_in, hw, hw))
    w = rand(seed + 1, (c_out, c_in, k, k))
    b = rand(seed + 2, (c_out,))
    np.testing.assert_allclose(
        pk_conv.conv2d(x, w, b, stride=stride, padding=pad),
        ref.conv2d_ref(x, w, b, stride=stride, padding=pad),
        rtol=1e-3,
        atol=1e-4,
    )


def test_conv2d_depthwise_groups():
    x = rand(20, (2, 6, 8, 8))
    w = rand(21, (6, 1, 3, 3))
    np.testing.assert_allclose(
        pk_conv.conv2d(x, w, None, stride=1, padding=1, groups=6),
        ref.conv2d_ref(x, w, None, stride=1, padding=1, groups=6),
        rtol=1e-3,
        atol=1e-4,
    )


# -------------------------------------------------------------- layernorm

@settings(max_examples=15, deadline=None)
@given(n=DIMS, d=st.integers(2, 64), seed=st.integers(0, 2**16))
def test_layernorm_matches_ref(n, d, seed):
    x = rand(seed, (n, d), -3, 3)
    g = rand(seed + 1, (d,), 0.5, 1.5)
    b = rand(seed + 2, (d,), -0.5, 0.5)
    np.testing.assert_allclose(
        pk_ln.layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=1e-4, atol=1e-5
    )


# ------------------------------------------------------------- lstm gates

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 16), h=st.integers(1, 32), seed=st.integers(0, 2**16))
def test_lstm_gates_match_ref(n, h, seed):
    pre = rand(seed, (n, 4 * h), -2, 2)
    c = rand(seed + 1, (n, h), -1, 1)
    h1, c1 = pk_lstm.lstm_gates(pre, c)
    h2, c2 = ref.lstm_gates_ref(pre, c)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-6)


def test_lstm_cell_full_step():
    x = rand(30, (4, 8))
    h = rand(31, (4, 16))
    c = rand(32, (4, 16))
    w_ih = rand(33, (64, 8))
    w_hh = rand(34, (64, 16))
    b = rand(35, (64,))
    h1, c1 = pk_lstm.lstm_cell(x, h, c, w_ih, w_hh, b)
    pre = ref.linear_ref(x, w_ih, b) + jnp.matmul(h, w_hh.T)
    h2, c2 = ref.lstm_gates_ref(pre, c)
    np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c1, c2, rtol=1e-4, atol=1e-5)


def test_lstm_hidden_bounded():
    pre = rand(36, (8, 64), -50, 50)
    c = rand(37, (8, 16), -5, 5)
    h1, _ = pk_lstm.lstm_gates(pre, c)
    assert np.abs(np.asarray(h1)).max() <= 1.0 + 1e-5
