"""L2 model-graph shape/semantics checks (pre-lowering)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def run_spec_once(spec):
    """Materialize example inputs and run the step function eagerly."""
    rng = np.random.default_rng(0)
    args = []
    for s in spec.example_inputs:
        if s.dtype == jnp.float32:
            args.append(jnp.asarray(rng.normal(0, 0.1, s.shape), jnp.float32))
        else:
            args.append(jnp.asarray(rng.integers(0, 4, s.shape), s.dtype))
    return spec.fn(*args), args


@pytest.mark.parametrize("make", [model.mlp_spec, model.ncf_spec, model.conv_block_spec])
def test_small_specs_run_and_return_declared_arity(make):
    spec = make()
    out, args = run_spec_once(spec)
    assert isinstance(out, tuple)
    n_out = len(out)
    # Train steps: loss + one updated tensor per param input.
    if spec.name.endswith("_step"):
        assert n_out == 1 + (len(args) - spec.n_batch_inputs)
        loss = out[0]
        assert loss.shape == ()
        assert np.isfinite(float(loss))
        for p_in, p_out in zip(args[spec.n_batch_inputs:], out[1:]):
            assert p_in.shape == p_out.shape
            assert p_in.dtype == p_out.dtype


def test_mlp_step_decreases_loss_on_fixed_batch():
    spec = model.mlp_spec()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (model.MLP_BATCH, model.MLP_IN)), jnp.float32)
    y = jnp.asarray(rng.integers(0, model.MLP_CLASSES, (model.MLP_BATCH,)))
    params = model.mlp_init(1)
    losses = []
    for _ in range(10):
        out = model.mlp_step(0.1, x, y, *params)
        losses.append(float(out[0]))
        params = list(out[1:])
    assert losses[-1] < losses[0], losses


def test_mlp_forward_matches_manual_composition():
    params = model.mlp_init(2)
    x = jnp.ones((4, model.MLP_IN), jnp.float32)
    got = model.mlp_forward(x, params)
    w1, b1, w2, b2 = params
    want = jnp.maximum(x @ w1.T + b1, 0.0) @ w2.T + b2
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_cnn_param_layout_matches_layer_list():
    layers = model.alexnet_layers()
    params = model.cnn_init(layers)
    convs = sum(1 for l in layers if l[0] == "conv")
    linears = sum(1 for l in layers if l[0] == "linear")
    assert len(params) == 2 * (convs + linears)
    # AlexNet: 5 convs + 3 linears.
    assert convs == 5 and linears == 3


def test_vgg19_has_19_weight_layers():
    layers = model.vgg19_layers()
    convs = sum(1 for l in layers if l[0] == "conv")
    linears = sum(1 for l in layers if l[0] == "linear")
    assert convs + linears == 19


def test_resnet50_param_count_and_forward_shape():
    params = model.resnet50_init()
    # 53 convs + fc, each with weight+bias.
    assert len(params) == 2 * 54
    x = jnp.zeros((2, 3, 32, 32), jnp.float32)
    logits = model.resnet50_forward(x, params)
    assert logits.shape == (2, 10)


def test_gnmt_loss_near_log_vocab_at_init():
    params = model.gnmt_init(0)
    rng = np.random.default_rng(3)
    src = jnp.asarray(rng.integers(0, model.GNMT_VOCAB, (4, model.GNMT_SRC)))
    tgt = jnp.asarray(rng.integers(0, model.GNMT_VOCAB, (4, model.GNMT_TGT)))
    loss = float(model.gnmt_forward_loss(params, src, tgt))
    assert abs(loss - np.log(model.GNMT_VOCAB)) < 2.0, loss


def test_ncf_predictions_are_probabilities():
    params = model.ncf_init(0)
    users = jnp.asarray([0, 5, 9])
    items = jnp.asarray([1, 2, 3])
    p = model.ncf_forward(params, users, items)
    assert p.shape == (3,)
    assert ((p >= 0) & (p <= 1)).all()


def test_all_specs_have_unique_names_and_valid_arity():
    specs = model.all_specs()
    names = [s.name for s in specs]
    assert len(set(names)) == len(names)
    for s in specs:
        assert 0 < s.n_batch_inputs <= len(s.example_inputs)
